//! Integration tests for the serving layer (`fyro::serve`): bitwise
//! solo-vs-batched parity, compiled-vs-dynamic Score parity,
//! mixed-version batching, backpressure, graceful shutdown, and
//! hot-swap semantics.

use fyro::dist::{Constraint, Normal};
use fyro::params::ParamStore;
use fyro::poutine::Ctx;
use fyro::serve::{
    loadgen, FrozenModel, Query, Registry, Request, Response, ServeConfig, ServeError,
    Server,
};
use fyro::tensor::Tensor;
use std::sync::{Arc, OnceLock};

/// The trained zoo (vae, gmm v1+v2, eight_schools) is expensive to
/// build, so share one registry across the tests that need it.
fn zoo() -> Arc<Registry> {
    static ZOO: OnceLock<Arc<Registry>> = OnceLock::new();
    ZOO.get_or_init(|| {
        fyro::telemetry::set_stderr_echo(false);
        let registry = Arc::new(Registry::new());
        let dir = std::env::temp_dir().join("fyro_test_serve_zoo");
        std::fs::create_dir_all(&dir).expect("zoo snapshot dir");
        loadgen::build_zoo(&registry, 40, dir.to_str().expect("utf-8 temp dir"))
            .expect("zoo build");
        registry
    })
    .clone()
}

// ---------------------------------------------------------- toy model

fn toy_model(ctx: &mut Ctx) {
    let z = ctx.sample("z", Normal::std(0.0, 1.0));
    ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.6));
}

fn toy_guide(ctx: &mut Ctx) {
    let loc = ctx.param("loc", || Tensor::scalar(0.0));
    let scale = ctx.param_constrained("scale", || Tensor::scalar(1.0), Constraint::Positive);
    ctx.sample("z", Normal::new(loc, scale));
}

/// Freeze the toy pair at a given version with a distinct guide `loc`,
/// so different versions produce measurably different Score losses.
fn toy_frozen(version: u64, loc: f64) -> Arc<FrozenModel> {
    let mut store = ParamStore::new();
    store.insert_unconstrained("loc", Tensor::scalar(loc), Constraint::Real);
    store.insert_unconstrained("scale", Tensor::scalar(-0.3), Constraint::Positive);
    FrozenModel::freeze("toy", version, Box::new(toy_model), Box::new(toy_guide), store)
        .expect("freeze toy")
}

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0)
}

fn score_loss(reply: Result<Response, ServeError>) -> f64 {
    match reply.expect("request served") {
        Response::Score { loss, .. } => loss,
        other => panic!("expected a Score response, got {other:?}"),
    }
}

// --------------------------------------------------------------- tests

/// A predictive request served inside a mixed concurrent batch must be
/// bitwise identical to the same request evaluated solo.
#[test]
fn solo_request_matches_batched_bitwise() {
    assert!(loadgen::check_solo_vs_batched(&zoo()));
}

/// Compiled Score path agrees with the dynamic interpreter to 1e-12
/// relative on the compilable zoo members; the gmm (discrete site)
/// stays honestly on the dynamic path.
#[test]
fn compiled_score_matches_dynamic_within_1e12() {
    assert!(loadgen::check_compiled_vs_dynamic(&zoo()));
}

/// A tiny admission queue under a burst rejects with `Overloaded`
/// (backpressure), while every accepted request still completes —
/// no deadlock, no dropped work.
#[test]
fn overload_rejects_without_dropping_accepted_work() {
    fyro::telemetry::set_stderr_echo(false);
    let (rejected, all_served) = loadgen::check_overload(&zoo());
    assert!(rejected > 0, "64 submits into depth-2 queue should overload");
    assert!(all_served, "every accepted request must be served");
}

/// Interleaved requests pinned to different versions of the same model
/// coalesce into batches, and each answer comes from the version the
/// request pinned at admission.
#[test]
fn mixed_version_batches_route_to_pinned_version() {
    let registry = Arc::new(Registry::new());
    registry.register(toy_frozen(1, 0.2)).expect("register v1");
    registry.register(toy_frozen(2, -0.7)).expect("register v2");
    let v1 = registry.get("toy", Some(1)).expect("v1 resolvable");
    let v2 = registry.get("toy", Some(2)).expect("v2 resolvable");
    // sanity: routing must be observable in the loss
    assert!(!close(v1.score_dynamic(100), v2.score_dynamic(100)));

    let server = Server::start(
        registry.clone(),
        ServeConfig { num_workers: 2, max_batch: 16, max_wait_us: 2000, queue_depth: 64 },
    );
    let pendings: Vec<_> = (0..12u64)
        .map(|i| {
            let version = 1 + i % 2;
            let p = server
                .submit(Request {
                    model: "toy".to_string(),
                    version: Some(version),
                    seed: 100 + i,
                    query: Query::Score,
                })
                .expect("admitted");
            (version, 100 + i, p)
        })
        .collect();
    for (version, seed, p) in pendings {
        let got = score_loss(p.wait());
        let want = registry
            .get("toy", Some(version))
            .expect("version registered")
            .score_dynamic(seed);
        assert!(
            close(got, want),
            "v{version} seed {seed}: served {got}, direct {want}"
        );
    }
    server.shutdown();
}

/// Shutdown stops admission, then drains: every request accepted before
/// the shutdown call is served before the threads exit.
#[test]
fn graceful_shutdown_drains_accepted_work() {
    let registry = Arc::new(Registry::new());
    registry.register(toy_frozen(1, 0.1)).expect("register");
    let server = Server::start(
        registry,
        ServeConfig { num_workers: 1, max_batch: 4, max_wait_us: 100, queue_depth: 64 },
    );
    let pendings: Vec<_> = (0..24u64)
        .map(|i| {
            server
                .submit(Request {
                    model: "toy".to_string(),
                    version: None,
                    seed: i,
                    query: Query::Score,
                })
                .expect("admitted")
        })
        .collect();
    server.shutdown();
    for p in pendings {
        assert!(p.wait().is_ok(), "accepted request dropped during shutdown");
    }
}

/// Hot-swap: registering v2 while the server is running atomically
/// moves the `version: None` default to v2, while requests pinned to v1
/// keep being served from v1 — and v1 results are unchanged.
#[test]
fn hot_swap_moves_default_without_disturbing_pinned_version() {
    let registry = Arc::new(Registry::new());
    registry.register(toy_frozen(1, 0.5)).expect("register v1");
    let server = Server::start(registry.clone(), ServeConfig::default());
    let v1_direct = registry.get("toy", Some(1)).expect("v1").score_dynamic(7);

    let latest_req = |version: Option<u64>| Request {
        model: "toy".to_string(),
        version,
        seed: 7,
        query: Query::Score,
    };
    let before = score_loss(server.serve(latest_req(None)));
    assert!(close(before, v1_direct), "pre-swap default must serve v1");

    registry.register(toy_frozen(2, -1.5)).expect("hot-swap v2");
    assert_eq!(registry.versions("toy"), vec![1, 2]);
    let v2_direct = registry.get("toy", Some(2)).expect("v2").score_dynamic(7);

    let after = score_loss(server.serve(latest_req(None)));
    assert!(close(after, v2_direct), "post-swap default must serve v2");
    assert!(!close(before, after), "swap must be observable");

    let pinned = score_loss(server.serve(latest_req(Some(1))));
    assert!(close(pinned, v1_direct), "pinned v1 unchanged after swap");
    server.shutdown();
}

/// Versions are immutable once registered, and unknown (model, version)
/// pairs are rejected at admission with `UnknownModel`.
#[test]
fn registry_rejects_duplicates_and_unknown_models() {
    let registry = Arc::new(Registry::new());
    registry.register(toy_frozen(1, 0.0)).expect("register v1");
    assert!(registry.register(toy_frozen(1, 0.3)).is_err(), "duplicate version");

    let server = Server::start(registry, ServeConfig::default());
    let unknown = server.submit(Request {
        model: "nope".to_string(),
        version: None,
        seed: 0,
        query: Query::Score,
    });
    assert!(matches!(unknown, Err(ServeError::UnknownModel(_))));
    let bad_version = server.submit(Request {
        model: "toy".to_string(),
        version: Some(9),
        seed: 0,
        query: Query::Score,
    });
    match bad_version {
        Err(ServeError::UnknownModel(m)) => assert!(m.contains("v9")),
        other => panic!("expected UnknownModel, got {other:?}"),
    }
    server.shutdown();
}

/// Freezing fails loudly when the pair touches a parameter the snapshot
/// does not carry — missing params are a registration-time error, not a
/// mid-request `[FY016]` panic.
#[test]
fn freeze_rejects_store_missing_params() {
    let empty = ParamStore::new();
    let res =
        FrozenModel::freeze("toy", 1, Box::new(toy_model), Box::new(toy_guide), empty);
    assert!(res.is_err(), "freeze must reject a store missing guide params");
}
