//! The static analyzer, end to end: a catalog of known-bad model/guide
//! pairs (one per lint code FY001–FY011, each asserting the exact code
//! and site/frame provenance), a zero-false-positive sweep over the
//! example zoo, the runtime-coded error messages (FY013–FY015), the
//! lenient-recording contract, `SviConfig::validate` / `Svi::analyze`
//! integration, the DCE bitwise pin, and the telemetry export path.
//!
//! The telemetry recorder and JSONL sink are process-global, so the
//! tests that emit or assert on them serialize on one mutex.

use fyro::analysis::{self, EstimatorHint, LintCode, Severity};
use fyro::infer::svi::{ModelFn, Svi, SviConfig};
use fyro::prelude::*;
use fyro::telemetry::{self, export};
use std::sync::Mutex;

static LOCK: Mutex<()> = Mutex::new(());

fn locked() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

// ------------------------------------------------- the reference pair

/// The conjugate scalar pair: z ~ N(0,1); x ~ N(z,1) observed at 0.6.
fn conj_model(ctx: &mut Ctx) {
    let z = ctx.sample("z", Normal::std(0.0, 1.0));
    ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.6));
}

fn conj_guide(ctx: &mut Ctx) {
    let loc = ctx.param("q.loc", || Tensor::scalar(0.0));
    let scale =
        ctx.param_constrained("q.scale", || Tensor::scalar(1.0), Constraint::Positive);
    ctx.sample("z", Normal::new(loc, scale));
}

fn lint(
    model: &dyn Fn(&mut Ctx),
    guide: &dyn Fn(&mut Ctx),
    est: Option<&EstimatorHint>,
) -> Report {
    let mut store = ParamStore::new();
    analysis::lint_model_guide(&mut store, 7, model, guide, est)
}

// ------------------------------------- catalog: one case per lint code

#[test]
fn fy001_guide_site_not_in_model() {
    let guide = |ctx: &mut Ctx| {
        ctx.sample("zz", Normal::std(0.0, 1.0)); // typo for "z"
    };
    let report = lint(&conj_model, &guide, None);
    let d = report.find(LintCode::GuideSiteNotInModel).expect("FY001");
    assert_eq!(d.code.code(), "FY001");
    assert_eq!(d.site.as_deref(), Some("zz"));
    assert_eq!(d.severity, Severity::Error);
}

#[test]
fn fy002_observed_site_in_guide() {
    // the guide samples "x", but the model observes it
    let guide = |ctx: &mut Ctx| {
        ctx.sample("x", Normal::std(0.0, 1.0));
    };
    let report = lint(&conj_model, &guide, None);
    let d = report.find(LintCode::ObservedSiteInGuide).expect("FY002");
    assert_eq!(d.code.code(), "FY002");
    assert_eq!(d.site.as_deref(), Some("x"));
    assert_eq!(d.severity, Severity::Error);

    // ...and the direct form: the guide calls observe itself
    let guide = |ctx: &mut Ctx| {
        ctx.observe("x", Normal::std(0.0, 1.0), Tensor::scalar(0.6));
    };
    let report = lint(&conj_model, &guide, None);
    let d = report.find(LintCode::ObservedSiteInGuide).expect("FY002 direct");
    assert_eq!(d.site.as_deref(), Some("x"));
}

#[test]
fn fy003_model_latent_not_in_guide() {
    let guide = |_ctx: &mut Ctx| {};
    let report = lint(&conj_model, &guide, None);
    let d = report.find(LintCode::ModelLatentNotInGuide).expect("FY003");
    assert_eq!(d.code.code(), "FY003");
    assert_eq!(d.site.as_deref(), Some("z"));
    assert_eq!(d.severity, Severity::Warning);
    assert!(!report.has_errors(), "prior fallback is a warning, not an error");
}

#[test]
fn fy004_plate_frame_mismatch() {
    // same plate name, different size between model (6) and guide (5)
    let model = |ctx: &mut Ctx| {
        ctx.plate("groups", 6, None, |ctx, _plate| {
            let theta = ctx.sample(
                "theta",
                Normal::new(ctx.c(Tensor::zeros(vec![6])), ctx.c(Tensor::ones(vec![6]))),
            );
            ctx.observe(
                "y",
                Normal::new(theta, ctx.cs(1.0)),
                Tensor::new(vec![0.0; 6], vec![6]),
            );
        });
    };
    let guide = |ctx: &mut Ctx| {
        ctx.plate("groups", 5, None, |ctx, _plate| {
            let loc = ctx.param("theta.loc", || Tensor::zeros(vec![5]));
            let scale = ctx.param_constrained(
                "theta.scale",
                || Tensor::ones(vec![5]),
                Constraint::Positive,
            );
            ctx.sample("theta", Normal::new(loc, scale));
        });
    };
    let report = lint(&model, &guide, None);
    let d = report.find(LintCode::PlateFrameMismatch).expect("FY004");
    assert_eq!(d.code.code(), "FY004");
    assert_eq!(d.site.as_deref(), Some("theta"));
    assert_eq!(d.frame.as_deref(), Some("groups"));
    assert_eq!(d.severity, Severity::Error);
}

#[test]
fn fy005_forgot_plate_select() {
    let data = Tensor::new(vec![0.0; 10], vec![10]);
    let model = move |ctx: &mut Ctx| {
        ctx.plate("data", 10, Some(3), |ctx, _plate| {
            // full 10-element data under a subsample-3 plate
            ctx.observe("x", Normal::std(0.0, 1.0), data.clone());
        });
    };
    let guide = |_ctx: &mut Ctx| {};
    let report = lint(&model, &guide, None);
    let d = report.find(LintCode::PlateShapeMismatch).expect("FY005");
    assert_eq!(d.code.code(), "FY005");
    assert_eq!(d.site.as_deref(), Some("x"));
    assert_eq!(d.frame.as_deref(), Some("data"));
    assert!(d.message.contains("forget `plate.select`"));
}

#[test]
fn fy006_mask_shape_mismatch() {
    // 4-element mask over a 3-element batch: cannot broadcast
    let inner = |ctx: &mut Ctx| {
        ctx.observe(
            "y",
            Normal::new(ctx.c(Tensor::zeros(vec![3])), ctx.c(Tensor::ones(vec![3]))),
            Tensor::new(vec![0.1, 0.2, 0.3], vec![3]),
        );
    };
    let model = fyro::poutine::mask(inner, Tensor::new(vec![1.0, 0.0, 1.0, 1.0], vec![4]));
    let guide = |_ctx: &mut Ctx| {};
    let report = lint(&model, &guide, None);
    let d = report.find(LintCode::MaskShapeMismatch).expect("FY006");
    assert_eq!(d.code.code(), "FY006");
    assert_eq!(d.site.as_deref(), Some("y"));
    assert_eq!(d.severity, Severity::Error);
}

#[test]
fn fy007_nonreparam_under_pathwise() {
    let model = |ctx: &mut Ctx| {
        let k = ctx.sample("k", Bernoulli::std(0.3));
        ctx.observe("x", Normal::new(k, ctx.cs(1.0)), Tensor::scalar(0.8));
    };
    let guide = |ctx: &mut Ctx| {
        let logit = ctx.param("k.logit", || Tensor::scalar(0.0));
        ctx.sample("k", Bernoulli::new(logit));
    };
    let pathwise = EstimatorHint { name: "Trace", variance_reduced: false };
    let report = lint(&model, &guide, Some(&pathwise));
    let d = report.find(LintCode::NonReparamUnderPathwise).expect("FY007");
    assert_eq!(d.code.code(), "FY007");
    assert_eq!(d.site.as_deref(), Some("k"));
    assert_eq!(d.severity, Severity::Warning);
    assert!(d.message.contains("TraceGraphElbo"));

    // the Rao-Blackwellized estimator silences the audit
    let rb = EstimatorHint { name: "TraceGraph", variance_reduced: true };
    let report = lint(&model, &guide, Some(&rb));
    assert!(!report.contains(LintCode::NonReparamUnderPathwise));
}

#[test]
fn fy008_observed_outside_support() {
    // 0.5 is not a Bernoulli outcome
    let model = |ctx: &mut Ctx| {
        ctx.observe("x", Bernoulli::std(0.3), Tensor::scalar(0.5));
    };
    let guide = |_ctx: &mut Ctx| {};
    let report = lint(&model, &guide, None);
    let d = report.find(LintCode::ObservedOutsideSupport).expect("FY008");
    assert_eq!(d.code.code(), "FY008");
    assert_eq!(d.site.as_deref(), Some("x"));
    assert_eq!(d.severity, Severity::Error);
}

#[test]
fn fy009_non_finite_param() {
    let guide = |ctx: &mut Ctx| {
        let loc = ctx.param("q.bad", || Tensor::scalar(f64::NAN));
        ctx.sample("z", Normal::new(loc, ctx.cs(1.0)));
    };
    let report = lint(&conj_model, &guide, None);
    let d = report.find(LintCode::NonFiniteParam).expect("FY009");
    assert_eq!(d.code.code(), "FY009");
    assert_eq!(d.frame.as_deref(), Some("q.bad"));
    assert_eq!(d.severity, Severity::Error);
}

#[test]
fn fy010_unused_param() {
    // first run leaves "stale" in the store; the second pair never
    // touches it
    let mut store = ParamStore::new();
    let stale_guide = |ctx: &mut Ctx| {
        let loc = ctx.param("stale", || Tensor::scalar(0.0));
        ctx.sample("z", Normal::new(loc, ctx.cs(1.0)));
    };
    let first =
        analysis::lint_model_guide(&mut store, 7, &conj_model, &stale_guide, None);
    assert!(first.is_clean(), "setup pair should lint clean: {first}");
    let report =
        analysis::lint_model_guide(&mut store, 7, &conj_model, &conj_guide, None);
    let d = report.find(LintCode::UnusedParam).expect("FY010");
    assert_eq!(d.code.code(), "FY010");
    assert_eq!(d.frame.as_deref(), Some("stale"));
    assert_eq!(d.severity, Severity::Warning);
}

#[test]
fn fy011_guide_param_no_gradient() {
    // params but no sample sites: nothing ever differentiates through
    let guide = |ctx: &mut Ctx| {
        ctx.param("dead", || Tensor::scalar(0.0));
    };
    let report = lint(&conj_model, &guide, None);
    let d = report.find(LintCode::GuideParamNoGradient).expect("FY011");
    assert_eq!(d.code.code(), "FY011");
    assert_eq!(d.frame.as_deref(), Some("dead"));
    assert_eq!(d.severity, Severity::Warning);
}

// ------------------------------------------- runtime-coded error paths

#[test]
#[should_panic(expected = "[FY013]")]
fn fy013_param_without_store_is_coded() {
    let model = |ctx: &mut Ctx| {
        ctx.param("p", || Tensor::scalar(0.0));
    };
    let mut rng = Pcg64::new(0);
    fyro::poutine::trace_fn(&model, &mut rng); // no ParamStore
}

#[test]
fn fy014_duplicate_site_is_coded() {
    let model = |ctx: &mut Ctx| {
        ctx.observe("x", Normal::std(0.0, 1.0), Tensor::scalar(0.1));
        let err = ctx
            .try_observe("x", Normal::std(0.0, 1.0), Tensor::scalar(0.2))
            .expect_err("duplicate site must error");
        assert!(format!("{err}").contains("[FY014]"), "got: {err}");
    };
    let mut rng = Pcg64::new(0);
    fyro::poutine::trace_fn(&model, &mut rng);
}

#[test]
#[should_panic(expected = "[FY015]")]
fn fy015_plate_subsample_range_is_coded() {
    let model = |ctx: &mut Ctx| {
        ctx.plate("data", 4, Some(9), |_ctx, _plate| {});
    };
    let mut rng = Pcg64::new(0);
    fyro::poutine::trace_fn(&model, &mut rng);
}

#[test]
fn lenient_recording_collects_instead_of_panicking() {
    // the same forgotten-select model that panics the strict runtime is
    // recorded to completion in lenient mode, with the error collected
    // under its stable code
    let data = Tensor::new(vec![0.0; 10], vec![10]);
    let model = move |ctx: &mut Ctx| {
        ctx.plate("data", 10, Some(3), |ctx, _plate| {
            ctx.observe("x", Normal::std(0.0, 1.0), data.clone());
        });
    };
    let guide = |_ctx: &mut Ctx| {};
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(0);
    let (model_trace, _guide_trace, errors) =
        analysis::record_pair(&mut store, &mut rng, &model, &guide);
    assert!(model_trace.get("x").is_some(), "skeleton recorded to completion");
    assert!(
        errors.iter().any(|e| format!("{e}").contains("[FY005]")),
        "lenient recording should collect the runtime FY005"
    );
}

// ------------------------------------------------- zero false positives

#[test]
fn zoo_sweep_has_zero_false_positives() {
    for pair in analysis::zoo::all() {
        let mut store = ParamStore::new();
        let report = analysis::lint_model_guide(
            &mut store,
            11,
            &pair.model,
            &pair.guide,
            Some(&pair.estimator),
        );
        assert!(
            report.is_clean(),
            "zoo pair '{}' should lint clean, got:\n{report}",
            pair.name
        );
    }
}

// ----------------------------------------------------- SVI integration

#[test]
fn svi_validate_gates_the_first_step() {
    let _g = locked(); // Svi::analyze emits through the global telemetry sink
    let bad_guide = |ctx: &mut Ctx| {
        ctx.sample("zz", Normal::std(0.0, 1.0));
    };
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(3);
    let mut svi = Svi::with_config(
        Adam::new(0.02),
        TraceElbo::default(),
        SviConfig { validate: true, ..SviConfig::default() },
    );
    let err = svi
        .try_step(&mut store, &mut rng, &conj_model, &bad_guide)
        .expect_err("first-step validation must reject the typo guide");
    let msg = format!("{err}");
    assert!(msg.contains("FY001"), "error should carry the lint code: {msg}");
    assert!(msg.contains("zz"), "error should name the offending site: {msg}");

    // the same engine trains a clean pair with validation still on
    let mut store = ParamStore::new();
    for _ in 0..5 {
        let loss = svi
            .try_step(&mut store, &mut rng, &conj_model, &conj_guide)
            .expect("clean pair passes validation");
        assert!(loss.is_finite());
    }
}

#[test]
fn svi_analyze_is_clean_on_the_reference_pair() {
    let svi = Svi::new(Adam::new(0.02), TraceElbo::default());
    let store = ParamStore::new();
    let report = svi.analyze(&store, 13, &conj_model, &conj_guide);
    assert!(report.is_clean(), "unexpected diagnostics: {report}");
}

// ------------------------------------------------------ DCE bitwise pin

#[test]
fn dce_is_bitwise_semantics_preserving() {
    let mut store = ParamStore::new();
    let audit = fyro::infer::dce_audit(
        21,
        &mut store,
        &conj_model as &ModelFn,
        &conj_guide as &ModelFn,
        &TraceElbo::default(),
    )
    .expect("conjugate pair is compilable");
    assert!(
        audit.bitwise_match,
        "pruned program must reproduce the raw program bit for bit: {audit:?}"
    );
    // the observation's constant data leaf receives adjoint edges in the
    // raw tape; liveness proves them dead
    assert!(audit.bw_eliminated >= 1, "expected dead backward work: {audit:?}");
    assert_eq!(audit.fw_eliminated, 0, "forward is already loss-pruned");
    assert!(
        audit.bw_eliminated < audit.bw_total,
        "the gradient path itself must survive: {audit:?}"
    );
}

// -------------------------------------------------- telemetry export

#[test]
fn lint_diagnostics_flow_through_the_warn_sink() {
    let _g = locked();
    telemetry::set_enabled(false);
    telemetry::reset();
    let path = std::env::temp_dir().join("fyro_test_analysis_events.jsonl");
    let _ = std::fs::remove_file(&path);
    export::set_jsonl_path(&path).expect("sink");

    telemetry::set_enabled(true);
    let bad_guide = |ctx: &mut Ctx| {
        ctx.sample("fy_probe_site", Normal::std(0.0, 1.0));
    };
    let report = lint(&conj_model, &bad_guide, None);
    assert!(report.contains(LintCode::GuideSiteNotInModel));
    report.emit();
    telemetry::set_enabled(false);
    export::clear_jsonl();

    let s = telemetry::snapshot();
    assert_eq!(s.counter("lint_diagnostics"), report.len() as u64);
    assert!(s.counter("warn_events") >= report.len() as u64);

    let text = std::fs::read_to_string(&path).expect("read events");
    let probe: Vec<&str> =
        text.lines().filter(|l| l.contains("fy_probe_site")).collect();
    assert_eq!(probe.len(), 1, "one FY001 event for the probe site:\n{text}");
    let fields = export::parse_jsonl_line(probe[0]).expect("event parses");
    let get = |k: &str| {
        fields.iter().find(|(n, _)| n == k).map(|(_, v)| v.as_str()).unwrap_or("")
    };
    assert_eq!(get("event"), "warn");
    assert_eq!(get("kind"), "lint");
    assert_eq!(get("code"), "FY001");
    assert_eq!(get("site"), "fy_probe_site");
    telemetry::reset();
    let _ = std::fs::remove_file(&path);
}

// ------------------------------------------------- catalog completeness

#[test]
fn every_code_has_stable_identity() {
    let codes: Vec<&str> = LintCode::ALL.iter().map(|c| c.code()).collect();
    assert_eq!(
        codes,
        vec![
            "FY001", "FY002", "FY003", "FY004", "FY005", "FY006", "FY007", "FY008",
            "FY009", "FY010", "FY011", "FY012", "FY013", "FY014", "FY015",
        ]
    );
    for c in LintCode::ALL {
        assert!(!c.name().is_empty());
        let _ = c.severity(); // total over the enum
    }
}
