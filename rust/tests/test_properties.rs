//! Cross-cutting property tests (testkit, the in-tree proptest stand-in):
//! invariants that must hold across the whole distribution library and
//! the trace machinery, not just for hand-picked cases.

use fyro::dist::kl::kl_normal_normal;
use fyro::prelude::*;
use fyro::testkit::{self, Config};

/// Every continuous distribution's samples must satisfy its declared
/// support constraint.
#[test]
fn samples_respect_declared_support() {
    let mut rng = Pcg64::new(0xA11CE);
    for _ in 0..200 {
        let dists: Vec<Box<dyn Dist<Tensor>>> = vec![
            Box::new(Normal::std(testkit::f64_in(&mut rng, -3.0, 3.0), 0.5)),
            Box::new(LogNormal::std(0.0, 1.0)),
            Box::new(Exponential::std(testkit::f64_in(&mut rng, 0.1, 5.0))),
            Box::new(Gamma::std(
                testkit::f64_in(&mut rng, 0.3, 5.0),
                testkit::f64_in(&mut rng, 0.3, 5.0),
            )),
            Box::new(Beta::std(
                testkit::f64_in(&mut rng, 0.5, 4.0),
                testkit::f64_in(&mut rng, 0.5, 4.0),
            )),
            Box::new(HalfCauchy::std(1.0)),
            Box::new(Uniform::std(-1.0, 2.0)),
            Box::new(Bernoulli::std(0.4)),
            Box::new(fyro::dist::Poisson::std(2.5)),
        ];
        for d in &dists {
            let s = d.sample(&mut rng);
            assert!(
                d.support().check(&s),
                "{} sample {s:?} violates {:?}",
                d.dist_name(),
                d.support()
            );
        }
    }
}

/// log_prob of a sample is finite for in-support values.
#[test]
fn log_prob_finite_at_samples() {
    let mut rng = Pcg64::new(0xB0B);
    for _ in 0..300 {
        let d = Gamma::std(
            testkit::f64_in(&mut rng, 0.3, 8.0),
            testkit::f64_in(&mut rng, 0.2, 8.0),
        );
        let s = d.sample(&mut rng);
        let lp = d.log_prob(&s).item();
        assert!(lp.is_finite(), "Gamma lp {lp} at {s:?}");
    }
}

/// Pathwise gradients: d sample / d loc == 1 for location families.
#[test]
fn location_family_reparam_gradient_is_one() {
    testkit::for_all(
        Config { cases: 32, seed: 0x10C },
        |rng| (testkit::f64_in(rng, -2.0, 2.0), testkit::f64_in(rng, 0.2, 3.0), rng.next_u64()),
        |&(loc, scale, seed)| {
            let tape = Tape::new();
            let l = tape.leaf(Tensor::scalar(loc));
            let s = tape.leaf(Tensor::scalar(scale));
            let d = Normal::new(l.clone(), s);
            let mut rng = Pcg64::new(seed);
            let z = d.sample(&mut rng);
            let g = tape.grad(&z.sum(), &[&l]).remove(0);
            testkit::close(g.item(), 1.0, 1e-12)
        },
    );
}

/// KL(p‖q) ≥ 0 with equality iff p == q, across random Normal pairs.
#[test]
fn kl_gap_matches_likelihood_ratio_expectation() {
    testkit::for_all(
        Config { cases: 10, seed: 0xD1CE },
        |rng| {
            (
                testkit::f64_in(rng, -1.0, 1.0),
                testkit::f64_in(rng, 0.5, 2.0),
                testkit::f64_in(rng, -1.0, 1.0),
                testkit::f64_in(rng, 0.5, 2.0),
            )
        },
        |&(m1, s1, m2, s2)| {
            let p = Normal::std(m1, s1);
            let q = Normal::std(m2, s2);
            let analytic = kl_normal_normal(&p, &q).item();
            // MC check
            let mut rng = Pcg64::new(7);
            let n = 60_000;
            let mut acc = 0.0;
            for _ in 0..n {
                let x = p.sample(&mut rng);
                acc += p.log_prob(&x).item() - q.log_prob(&x).item();
            }
            testkit::close(analytic, acc / n as f64, 0.03)
        },
    );
}

/// Trace invariant: replaying a trace into its own model reproduces the
/// same log-joint (replay is idempotent).
#[test]
fn replay_is_idempotent_on_log_joint() {
    testkit::for_all(
        Config { cases: 24, seed: 0x4E9 },
        |rng| rng.next_u64(),
        |&seed| {
            let model = |ctx: &mut Ctx| {
                let a = ctx.sample("a", Normal::std(0.0, 1.0));
                let b = ctx.sample("b", LogNormal::new(a.clone(), ctx.cs(0.5)));
                ctx.observe("x", Normal::new(b, ctx.cs(1.0)), Tensor::scalar(1.0));
            };
            let mut rng = Pcg64::new(seed);
            let t1 = fyro::poutine::trace_fn(&model, &mut rng);
            let replayed = fyro::poutine::replay(model, t1.clone());
            let t2 = fyro::poutine::trace_fn(&replayed, &mut rng);
            testkit::close(t1.log_prob_sum(), t2.log_prob_sum(), 1e-10)
        },
    );
}

/// Scale handler linearity: scale(model, a) then scale(.., b) multiplies
/// log-probs by a*b for any positive a, b.
#[test]
fn scale_handlers_compose_linearly() {
    testkit::for_all(
        Config { cases: 24, seed: 0x5CA1E },
        |rng| (testkit::f64_in(rng, 0.1, 5.0), testkit::f64_in(rng, 0.1, 5.0), rng.next_u64()),
        |&(a, b, seed)| {
            let model = |ctx: &mut Ctx| {
                ctx.observe("x", Normal::std(0.0, 1.0), Tensor::scalar(0.7));
            };
            let mut rng1 = Pcg64::new(seed);
            let base = fyro::poutine::trace_fn(&model, &mut rng1).log_prob_sum();
            let scaled = fyro::poutine::scale(fyro::poutine::scale(model, a), b);
            let mut rng2 = Pcg64::new(seed);
            let got = fyro::poutine::trace_fn(&scaled, &mut rng2).log_prob_sum();
            testkit::close(got, a * b * base, 1e-10)
        },
    );
}

/// Autodiff: the gradient of any composite of Field ops matches finite
/// differences (random expression fuzzing over a fixed op basis).
#[test]
fn autodiff_matches_finite_differences_on_random_programs() {
    testkit::for_all(
        Config { cases: 24, seed: 0xFD },
        |rng| {
            let n = 1 + rng.below(5);
            let data: Vec<f64> = (0..n).map(|_| 0.3 + rng.uniform() * 2.0).collect();
            let ops: Vec<usize> = (0..4).map(|_| rng.below(6)).collect();
            (data, ops)
        },
        |(data, ops)| {
            let apply = |tape: &Tape, x0: Tensor| -> f64 {
                let mut v = tape.leaf(x0);
                for &op in ops {
                    v = match op {
                        0 => v.exp().mul_scalar(0.3),
                        1 => v.softplus(),
                        2 => v.square().add_scalar(0.1),
                        3 => v.sigmoid(),
                        4 => v.sqrt(),
                        _ => v.tanh().add_scalar(1.5),
                    };
                }
                v.sum().item()
            };
            // AD gradient
            let tape = Tape::new();
            let mut v = tape.leaf(Tensor::from_vec(data.clone()));
            let leaf = v.clone();
            for &op in ops {
                v = match op {
                    0 => v.exp().mul_scalar(0.3),
                    1 => v.softplus(),
                    2 => v.square().add_scalar(0.1),
                    3 => v.sigmoid(),
                    4 => v.sqrt(),
                    _ => v.tanh().add_scalar(1.5),
                };
            }
            let g = tape.grad(&v.sum(), &[&leaf]).remove(0);
            // finite differences
            let eps = 1e-6;
            for i in 0..data.len() {
                let mut plus = data.clone();
                plus[i] += eps;
                let mut minus = data.clone();
                minus[i] -= eps;
                let tp = Tape::new();
                let tm = Tape::new();
                let fd = (apply(&tp, Tensor::from_vec(plus)) - apply(&tm, Tensor::from_vec(minus)))
                    / (2.0 * eps);
                testkit::close(g.data()[i], fd, 1e-4)?;
            }
            Ok(())
        },
    );
}

/// The vectorized `plate` (one broadcast site) and the retained
/// sequential `plate_seq` (one site per index) must assign the same
/// scaled log-joint for identical seeds, across random sizes and
/// subsample sizes.
#[test]
fn vectorized_plate_log_joint_matches_sequential() {
    testkit::for_all(
        Config { cases: 40, seed: 0x91A7E5 },
        |rng| {
            let n = 1 + rng.below(24);
            let m = 1 + rng.below(n);
            let data: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            (n, m, data, rng.next_u64())
        },
        |(n, m, data, seed)| {
            let (n, m) = (*n, *m);
            let data_t = Tensor::from_vec(data.clone());
            let dv = data_t.clone();
            let vec_model = move |ctx: &mut Ctx| {
                let mu = ctx.sample("mu", Normal::std(0.0, 10.0));
                ctx.plate("data", n, Some(m), |ctx, plate| {
                    ctx.observe(
                        "x",
                        Normal::new(mu.clone(), ctx.cs(1.0)),
                        plate.select(&dv),
                    );
                });
            };
            let ds = data_t.clone();
            let seq_model = move |ctx: &mut Ctx| {
                let mu = ctx.sample("mu", Normal::std(0.0, 10.0));
                ctx.plate_seq("data", n, Some(m), |ctx, idx| {
                    for &i in idx {
                        ctx.observe(
                            &format!("x_{i}"),
                            Normal::new(mu.clone(), ctx.cs(1.0)),
                            Tensor::scalar(ds.data()[i]),
                        );
                    }
                });
            };
            let mut rng1 = Pcg64::new(*seed);
            let lv = fyro::poutine::trace_fn(&vec_model, &mut rng1).log_prob_sum();
            let mut rng2 = Pcg64::new(*seed);
            let ls = fyro::poutine::trace_fn(&seq_model, &mut rng2).log_prob_sum();
            testkit::close(lv, ls, 1e-10)
        },
    );
}

/// Full ELBO equivalence: a guide/model pair evaluated through
/// `TraceElbo` must produce the same ELBO under the vectorized and
/// sequential plate for identical seeds (fresh stores each side).
#[test]
fn vectorized_plate_elbo_matches_sequential() {
    use fyro::infer::elbo::TraceElbo;
    use fyro::infer::svi::trace_pair;
    testkit::for_all(
        Config { cases: 24, seed: 0xE1B0E5 },
        |rng| {
            let n = 2 + rng.below(16);
            let m = 1 + rng.below(n);
            let data: Vec<f64> = (0..n).map(|_| 0.5 + rng.normal()).collect();
            (n, m, data, rng.next_u64())
        },
        |(n, m, data, seed)| {
            let (n, m) = (*n, *m);
            let data_t = Tensor::from_vec(data.clone());
            let guide = |ctx: &mut Ctx| {
                let loc = ctx.param("mu.loc", || Tensor::scalar(0.1));
                let scale = ctx.param_constrained(
                    "mu.scale",
                    || Tensor::scalar(0.7),
                    Constraint::Positive,
                );
                ctx.sample("mu", Normal::new(loc, scale));
            };
            let run = |vectorized: bool| -> f64 {
                let dt = data_t.clone();
                let vec_model = move |ctx: &mut Ctx| {
                    let mu = ctx.sample("mu", Normal::std(0.0, 10.0));
                    ctx.plate("data", n, Some(m), |ctx, plate| {
                        ctx.observe(
                            "x",
                            Normal::new(mu.clone(), ctx.cs(1.0)),
                            plate.select(&dt),
                        );
                    });
                };
                let dt2 = data_t.clone();
                let seq_model = move |ctx: &mut Ctx| {
                    let mu = ctx.sample("mu", Normal::std(0.0, 10.0));
                    ctx.plate_seq("data", n, Some(m), |ctx, idx| {
                        for &i in idx {
                            ctx.observe(
                                &format!("x_{i}"),
                                Normal::new(mu.clone(), ctx.cs(1.0)),
                                Tensor::scalar(dt2.data()[i]),
                            );
                        }
                    });
                };
                let mut store = ParamStore::new();
                let mut rng = Pcg64::new(*seed);
                let (mt, gt) = if vectorized {
                    trace_pair(&mut store, &mut rng, &vec_model, &guide)
                } else {
                    trace_pair(&mut store, &mut rng, &seq_model, &guide)
                };
                let (_, elbo) =
                    TraceElbo::loss_with_baseline(&mt, &gt, None).expect("elbo");
                elbo
            };
            testkit::close(run(true), run(false), 1e-10)
        },
    );
}

/// Nested plates: scales compose multiplicatively and the site's
/// `cond_indep_stack` carries both frames, for random sizes/subsamples.
#[test]
fn nested_plate_scale_composition_property() {
    testkit::for_all(
        Config { cases: 32, seed: 0x2E57ED },
        |rng| {
            let no = 1 + rng.below(8);
            let mo = 1 + rng.below(no);
            let ni = 1 + rng.below(8);
            let mi = 1 + rng.below(ni);
            (no, mo, ni, mi, rng.next_u64())
        },
        |&(no, mo, ni, mi, seed)| {
            let model = move |ctx: &mut Ctx| {
                ctx.plate("o", no, Some(mo), |ctx, po| {
                    let mo_now = po.len();
                    ctx.plate("i", ni, Some(mi), |ctx, pi| {
                        let mi_now = pi.len();
                        ctx.observe(
                            "x",
                            Normal::new(
                                ctx.c(Tensor::zeros(vec![mi_now, mo_now])),
                                ctx.c(Tensor::ones(vec![mi_now, mo_now])),
                            ),
                            Tensor::zeros(vec![mi_now, mo_now]),
                        );
                    });
                });
            };
            let mut rng = Pcg64::new(seed);
            let t = fyro::poutine::trace_fn(&model, &mut rng);
            let s = t.get("x").unwrap();
            let want = (no as f64 / mo as f64) * (ni as f64 / mi as f64);
            testkit::close(s.scale, want, 1e-12)?;
            testkit::ensure(
                s.cond_indep_stack.len() == 2
                    && s.cond_indep_stack[0].name == "i"
                    && s.cond_indep_stack[0].dim == 1
                    && s.cond_indep_stack[1].name == "o"
                    && s.cond_indep_stack[1].dim == 0,
                "cond_indep_stack frames wrong",
            )?;
            // scaled joint == full-population-equivalent of the zeros obs
            let per = -0.5 * fyro::dist::LN_2PI;
            testkit::close(t.log_prob_sum(), (no * ni) as f64 * per, 1e-9)
        },
    );
}

/// Masks apply to the batch-shaped (event-reduced) log-prob: a batch
/// mask over an event-carrying site knocks out whole joint rows.
#[test]
fn mask_broadcasts_over_event_reduced_log_prob() {
    let model = |ctx: &mut Ctx| {
        ctx.observe(
            "x",
            MvNormalDiag::new(
                ctx.c(Tensor::zeros(vec![3, 2])),
                ctx.c(Tensor::ones(vec![3, 2])),
            ),
            Tensor::new(vec![0.0, 0.0, 10.0, 10.0, 0.0, 0.0], vec![3, 2]),
        );
    };
    let masked = fyro::poutine::mask(model, Tensor::from_vec(vec![1.0, 0.0, 1.0]));
    let mut rng = Pcg64::new(1);
    let t = fyro::poutine::trace_fn(&masked, &mut rng);
    // rows 0 and 2 survive: 2 rows x 2 event dims of standard normal at 0
    let per = -0.5 * fyro::dist::LN_2PI;
    assert!((t.log_prob_sum() - 4.0 * per).abs() < 1e-10);
    // the outlier row (masked out) contributes nothing
    let site = t.get("x").unwrap();
    assert_eq!(site.log_prob_batch().value().dims(), &[3]);
}

/// Importance-sampling evidence estimates must be consistent between
/// prior proposals and (imperfect but overlapping) guide proposals.
#[test]
fn evidence_estimates_agree_across_proposals() {
    let model = |ctx: &mut Ctx| {
        let z = ctx.sample("z", Normal::std(0.0, 1.0));
        ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.4));
    };
    let guide = |ctx: &mut Ctx| {
        ctx.sample("z", Normal::std(0.1, 0.9));
    };
    let mut rng = Pcg64::new(99);
    let a = fyro::infer::Importance::from_prior(&model, 30_000, &mut rng).log_evidence();
    let b = fyro::infer::Importance::with_guide(&model, &guide, 30_000, &mut rng)
        .log_evidence();
    let exact = Normal::std(0.0, 2.0f64.sqrt())
        .log_prob(&Tensor::scalar(0.4))
        .item();
    assert!((a - exact).abs() < 0.02, "prior-proposal evidence {a} vs {exact}");
    assert!((b - exact).abs() < 0.02, "guide-proposal evidence {b} vs {exact}");
}

/// (a) On fully reparameterized models (no score-function sites), the
/// Rao-Blackwellized `TraceGraphElbo` must produce EXACTLY the plain
/// `TraceElbo` surrogate loss — same value, same gradients — across
/// random plate sizes and subsamples.
#[test]
fn tracegraph_equals_trace_on_fully_reparam_models() {
    use fyro::infer::elbo::TraceGraphElbo;
    use fyro::infer::svi::trace_pair;
    testkit::for_all(
        Config { cases: 24, seed: 0x76A9 },
        |rng| {
            let n = 2 + rng.below(12);
            let m = 1 + rng.below(n);
            let data: Vec<f64> = (0..n).map(|_| 0.4 + rng.normal()).collect();
            (n, m, data, rng.next_u64())
        },
        |(n, m, data, seed)| {
            let (n, m) = (*n, *m);
            let data_t = Tensor::from_vec(data.clone());
            let model = move |ctx: &mut Ctx| {
                let mu = ctx.sample("mu", Normal::std(0.0, 10.0));
                ctx.plate("data", n, Some(m), |ctx, plate| {
                    ctx.observe(
                        "x",
                        Normal::new(mu.clone(), ctx.cs(1.0)),
                        plate.select(&data_t),
                    );
                });
            };
            let guide = |ctx: &mut Ctx| {
                let loc = ctx.param("mu.loc", || Tensor::scalar(0.2));
                let scale = ctx.param_constrained(
                    "mu.scale",
                    || Tensor::scalar(0.6),
                    Constraint::Positive,
                );
                ctx.sample("mu", Normal::new(loc, scale));
            };
            let mut store = ParamStore::new();
            let mut rng = Pcg64::new(*seed);
            let (mt, gt) = trace_pair(&mut store, &mut rng, &model, &guide);
            let (lg, vg) = TraceGraphElbo::default().loss(&mt, &gt).expect("tracegraph");
            let (lt, vt) = TraceElbo::default().loss(&mt, &gt).expect("trace");
            testkit::close(lg.item(), lt.item(), 1e-12)?;
            testkit::close(vg, vt, 1e-12)?;
            // gradients w.r.t. every guide param leaf, same leaf order
            let leaves: Vec<&Var> = gt.param_leaves.values().collect();
            let gg = lg.tape().grad(&lg, &leaves);
            let gte = lt.tape().grad(&lt, &leaves);
            for (a, b) in gg.iter().zip(&gte) {
                testkit::close(a.item(), b.item(), 1e-12)?;
            }
            Ok(())
        },
    );
}

/// (b) `RenyiElbo` at one particle degenerates exactly to `TraceElbo`:
/// identical loss trajectories and identical learned parameters to
/// 1e-12, across random seeds — including on a model with a
/// score-function (discrete) guide site.
#[test]
fn renyi_single_particle_equals_trace_property() {
    use fyro::infer::svi::SviConfig;
    testkit::for_all(
        Config { cases: 8, seed: 0x21A1 },
        |rng| (rng.next_u64(), rng.below(2) == 1),
        |&(seed, discrete)| {
            let model = move |ctx: &mut Ctx| {
                if discrete {
                    let z = ctx.sample("z", Bernoulli::std(0.5));
                    let logits = z.mul_scalar(6.0).add_scalar(-3.0);
                    ctx.observe("x", Bernoulli::new(logits), Tensor::scalar(1.0));
                } else {
                    let z = ctx.sample("z", Normal::std(0.0, 1.0));
                    ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.6));
                }
            };
            let guide = move |ctx: &mut Ctx| {
                let p = ctx.param("q_p", || Tensor::scalar(0.1));
                if discrete {
                    ctx.sample("z", Bernoulli::new(p));
                } else {
                    ctx.sample("z", Normal::new(p, ctx.cs(0.8)));
                }
            };
            let cfg = SviConfig { num_particles: 1, ..SviConfig::default() };
            let run_trace = |_: ()| -> (Vec<f64>, f64) {
                let mut store = ParamStore::new();
                let mut rng = Pcg64::new(seed);
                let mut svi = Svi::with_config(Adam::new(0.03), TraceElbo::default(), cfg);
                let l = (0..25)
                    .map(|_| svi.step(&mut store, &mut rng, &model, &guide))
                    .collect();
                (l, store.get_unconstrained("q_p").unwrap().item())
            };
            let run_renyi = |_: ()| -> (Vec<f64>, f64) {
                let mut store = ParamStore::new();
                let mut rng = Pcg64::new(seed);
                let mut svi = Svi::with_config(Adam::new(0.03), RenyiElbo::iwae(), cfg);
                let l = (0..25)
                    .map(|_| svi.step(&mut store, &mut rng, &model, &guide))
                    .collect();
                (l, store.get_unconstrained("q_p").unwrap().item())
            };
            let (lt, pt) = run_trace(());
            let (lr, pr) = run_renyi(());
            for (a, b) in lt.iter().zip(&lr) {
                testkit::close(*a, *b, 1e-12)?;
            }
            testkit::close(pt, pr, 1e-12)
        },
    );
}

/// Brute-force reference for the Rao-Blackwellized downstream cost: for
/// every element of `z`'s batched log-prob, loop over ALL downstream
/// sites and ALL their elements, including a term only when it matches
/// `z`'s element on every shared plate dim.
fn reference_downstream_cost(
    z_name: &str,
    mt: &fyro::poutine::Trace,
    gt: &fyro::poutine::Trace,
) -> Tensor {
    use fyro::poutine::Site;
    fn coord(dims: &[usize], flat: usize, axis: usize) -> usize {
        let mut rem = flat;
        for (i, _) in dims.iter().enumerate() {
            let stride: usize = dims[i + 1..].iter().product();
            let c = rem / stride;
            rem %= stride;
            if i == axis {
                return c;
            }
        }
        0
    }
    let z = gt.get(z_name).unwrap();
    let gz = gt.index_of(z_name).unwrap();
    let mz = mt.index_of(z_name).unwrap_or(0);
    let z_dims = z.log_prob_batch().value().dims().to_vec();
    let z_rank = z_dims.len();
    let numel: usize = z_dims.iter().product::<usize>().max(1);
    let mut out = vec![0.0; numel];
    let add_site = |site: &Site, sign: f64, out: &mut Vec<f64>| {
        let lp = site.log_prob_batch().value().mul_scalar(site.scale * sign);
        let dims = lp.dims().to_vec();
        // shared plates: contiguous dims 0,1,… carried by BOTH sites
        // under the same plate name
        let mut shared = Vec::new();
        let mut d = 0;
        loop {
            let fz = z.frames().iter().find(|f| f.dim == d);
            let fj = site.frames().iter().find(|f| f.dim == d);
            match (fz, fj) {
                (Some(a), Some(b)) if a.name == b.name => {
                    shared.push(d);
                    d += 1;
                }
                _ => break,
            }
        }
        for e in 0..numel {
            for (f, &v) in lp.data().iter().enumerate() {
                let matches = shared.iter().all(|&dd| {
                    let zc = if z_rank > dd {
                        coord(&z_dims, e, z_rank - 1 - dd)
                    } else {
                        return true;
                    };
                    let jc = if dims.len() > dd {
                        coord(&dims, f, dims.len() - 1 - dd)
                    } else {
                        return true;
                    };
                    zc == jc
                });
                if matches {
                    out[e] += v;
                }
            }
        }
    };
    for (mi, s) in mt.sites().iter().enumerate() {
        if mi < mz || s.intervened {
            continue;
        }
        add_site(s, 1.0, &mut out);
    }
    for (gi, s) in gt.sites().iter().enumerate() {
        if gi < gz || s.is_observed || s.intervened {
            continue;
        }
        add_site(s, -1.0, &mut out);
    }
    if z_dims.is_empty() {
        Tensor::scalar(out[0])
    } else {
        Tensor::new(out, z_dims)
    }
}

/// (c) The production Rao-Blackwellized downstream-cost computation
/// must match the brute-force per-element reference on random nested
/// plate graphs with discrete sites at every level.
#[test]
fn rao_blackwell_downstream_cost_matches_bruteforce() {
    use fyro::infer::elbo::rao_blackwell_downstream_cost;
    use fyro::infer::svi::trace_pair;
    testkit::for_all(
        Config { cases: 16, seed: 0x2B5D },
        |rng| {
            let no = 1 + rng.below(4);
            let ni = 1 + rng.below(4);
            (no, ni, rng.next_u64())
        },
        |&(no, ni, seed)| {
            let mut drng = Pcg64::new(seed ^ 0xDA7A);
            let data_out = Tensor::randn(vec![no], &mut drng);
            let data_in = Tensor::randn(vec![ni, no], &mut drng);
            let model = {
                let (data_out, data_in) = (data_out.clone(), data_in.clone());
                move |ctx: &mut Ctx| {
                    let t = ctx.sample("b_top", Bernoulli::std(0.3));
                    ctx.plate("outer", no, None, |ctx, _p| {
                        let bo = ctx
                            .sample("b_out", Bernoulli::new(ctx.c(Tensor::zeros(vec![no]))));
                        ctx.observe(
                            "x_out",
                            Normal::new(bo.add(&t), ctx.cs(1.0)),
                            data_out.clone(),
                        );
                        ctx.plate("inner", ni, None, |ctx, _p| {
                            let bi = ctx.sample(
                                "b_in",
                                Bernoulli::new(ctx.c(Tensor::zeros(vec![ni, no]))),
                            );
                            ctx.observe(
                                "x_in",
                                Normal::new(bi, ctx.cs(1.0)),
                                data_in.clone(),
                            );
                        });
                    });
                }
            };
            let guide = move |ctx: &mut Ctx| {
                let lt = ctx.param("lt", || Tensor::scalar(0.2));
                ctx.sample("b_top", Bernoulli::new(lt));
                ctx.plate("outer", no, None, |ctx, _p| {
                    let lo = ctx.param("lo", || Tensor::full(vec![no], -0.1));
                    ctx.sample("b_out", Bernoulli::new(lo));
                    ctx.plate("inner", ni, None, |ctx, _p| {
                        let li = ctx.param("li", || Tensor::full(vec![ni, no], 0.3));
                        ctx.sample("b_in", Bernoulli::new(li));
                    });
                });
            };
            let mut store = ParamStore::new();
            let mut rng = Pcg64::new(seed);
            let (mt, gt) = trace_pair(&mut store, &mut rng, &model, &guide);
            for name in ["b_top", "b_out", "b_in"] {
                let z = gt.get(name).unwrap();
                let gz = gt.index_of(name).unwrap();
                let got = rao_blackwell_downstream_cost(z, gz, &mt, &gt);
                let want = reference_downstream_cost(name, &mt, &gt);
                let got_b = got.broadcast_to(want.dims().to_vec());
                testkit::ensure(
                    got_b.allclose(&want, 1e-10),
                    format!(
                        "site '{name}': computed {:?} vs reference {:?}",
                        got_b.to_vec(),
                        want.to_vec()
                    ),
                )?;
            }
            Ok(())
        },
    );
}
