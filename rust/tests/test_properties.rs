//! Cross-cutting property tests (testkit, the in-tree proptest stand-in):
//! invariants that must hold across the whole distribution library and
//! the trace machinery, not just for hand-picked cases.

use fyro::dist::kl::kl_normal_normal;
use fyro::prelude::*;
use fyro::testkit::{self, Config};

/// Every continuous distribution's samples must satisfy its declared
/// support constraint.
#[test]
fn samples_respect_declared_support() {
    let mut rng = Pcg64::new(0xA11CE);
    for _ in 0..200 {
        let dists: Vec<Box<dyn Dist<Tensor>>> = vec![
            Box::new(Normal::std(testkit::f64_in(&mut rng, -3.0, 3.0), 0.5)),
            Box::new(LogNormal::std(0.0, 1.0)),
            Box::new(Exponential::std(testkit::f64_in(&mut rng, 0.1, 5.0))),
            Box::new(Gamma::std(
                testkit::f64_in(&mut rng, 0.3, 5.0),
                testkit::f64_in(&mut rng, 0.3, 5.0),
            )),
            Box::new(Beta::std(
                testkit::f64_in(&mut rng, 0.5, 4.0),
                testkit::f64_in(&mut rng, 0.5, 4.0),
            )),
            Box::new(HalfCauchy::std(1.0)),
            Box::new(Uniform::std(-1.0, 2.0)),
            Box::new(Bernoulli::std(0.4)),
            Box::new(fyro::dist::Poisson::std(2.5)),
        ];
        for d in &dists {
            let s = d.sample(&mut rng);
            assert!(
                d.support().check(&s),
                "{} sample {s:?} violates {:?}",
                d.dist_name(),
                d.support()
            );
        }
    }
}

/// log_prob of a sample is finite for in-support values.
#[test]
fn log_prob_finite_at_samples() {
    let mut rng = Pcg64::new(0xB0B);
    for _ in 0..300 {
        let d = Gamma::std(
            testkit::f64_in(&mut rng, 0.3, 8.0),
            testkit::f64_in(&mut rng, 0.2, 8.0),
        );
        let s = d.sample(&mut rng);
        let lp = d.log_prob(&s).item();
        assert!(lp.is_finite(), "Gamma lp {lp} at {s:?}");
    }
}

/// Pathwise gradients: d sample / d loc == 1 for location families.
#[test]
fn location_family_reparam_gradient_is_one() {
    testkit::for_all(
        Config { cases: 32, seed: 0x10C },
        |rng| (testkit::f64_in(rng, -2.0, 2.0), testkit::f64_in(rng, 0.2, 3.0), rng.next_u64()),
        |&(loc, scale, seed)| {
            let tape = Tape::new();
            let l = tape.leaf(Tensor::scalar(loc));
            let s = tape.leaf(Tensor::scalar(scale));
            let d = Normal::new(l.clone(), s);
            let mut rng = Pcg64::new(seed);
            let z = d.sample(&mut rng);
            let g = tape.grad(&z.sum(), &[&l]).remove(0);
            testkit::close(g.item(), 1.0, 1e-12)
        },
    );
}

/// KL(p‖q) ≥ 0 with equality iff p == q, across random Normal pairs.
#[test]
fn kl_gap_matches_likelihood_ratio_expectation() {
    testkit::for_all(
        Config { cases: 10, seed: 0xD1CE },
        |rng| {
            (
                testkit::f64_in(rng, -1.0, 1.0),
                testkit::f64_in(rng, 0.5, 2.0),
                testkit::f64_in(rng, -1.0, 1.0),
                testkit::f64_in(rng, 0.5, 2.0),
            )
        },
        |&(m1, s1, m2, s2)| {
            let p = Normal::std(m1, s1);
            let q = Normal::std(m2, s2);
            let analytic = kl_normal_normal(&p, &q).item();
            // MC check
            let mut rng = Pcg64::new(7);
            let n = 60_000;
            let mut acc = 0.0;
            for _ in 0..n {
                let x = p.sample(&mut rng);
                acc += p.log_prob(&x).item() - q.log_prob(&x).item();
            }
            testkit::close(analytic, acc / n as f64, 0.03)
        },
    );
}

/// Trace invariant: replaying a trace into its own model reproduces the
/// same log-joint (replay is idempotent).
#[test]
fn replay_is_idempotent_on_log_joint() {
    testkit::for_all(
        Config { cases: 24, seed: 0x4E9 },
        |rng| rng.next_u64(),
        |&seed| {
            let model = |ctx: &mut Ctx| {
                let a = ctx.sample("a", Normal::std(0.0, 1.0));
                let b = ctx.sample("b", LogNormal::new(a.clone(), ctx.cs(0.5)));
                ctx.observe("x", Normal::new(b, ctx.cs(1.0)), Tensor::scalar(1.0));
            };
            let mut rng = Pcg64::new(seed);
            let t1 = fyro::poutine::trace_fn(&model, &mut rng);
            let replayed = fyro::poutine::replay(model, t1.clone());
            let t2 = fyro::poutine::trace_fn(&replayed, &mut rng);
            testkit::close(t1.log_prob_sum(), t2.log_prob_sum(), 1e-10)
        },
    );
}

/// Scale handler linearity: scale(model, a) then scale(.., b) multiplies
/// log-probs by a*b for any positive a, b.
#[test]
fn scale_handlers_compose_linearly() {
    testkit::for_all(
        Config { cases: 24, seed: 0x5CA1E },
        |rng| (testkit::f64_in(rng, 0.1, 5.0), testkit::f64_in(rng, 0.1, 5.0), rng.next_u64()),
        |&(a, b, seed)| {
            let model = |ctx: &mut Ctx| {
                ctx.observe("x", Normal::std(0.0, 1.0), Tensor::scalar(0.7));
            };
            let mut rng1 = Pcg64::new(seed);
            let base = fyro::poutine::trace_fn(&model, &mut rng1).log_prob_sum();
            let scaled = fyro::poutine::scale(fyro::poutine::scale(model, a), b);
            let mut rng2 = Pcg64::new(seed);
            let got = fyro::poutine::trace_fn(&scaled, &mut rng2).log_prob_sum();
            testkit::close(got, a * b * base, 1e-10)
        },
    );
}

/// Autodiff: the gradient of any composite of Field ops matches finite
/// differences (random expression fuzzing over a fixed op basis).
#[test]
fn autodiff_matches_finite_differences_on_random_programs() {
    testkit::for_all(
        Config { cases: 24, seed: 0xFD },
        |rng| {
            let n = 1 + rng.below(5);
            let data: Vec<f64> = (0..n).map(|_| 0.3 + rng.uniform() * 2.0).collect();
            let ops: Vec<usize> = (0..4).map(|_| rng.below(6)).collect();
            (data, ops)
        },
        |(data, ops)| {
            let apply = |tape: &Tape, x0: Tensor| -> f64 {
                let mut v = tape.leaf(x0);
                for &op in ops {
                    v = match op {
                        0 => v.exp().mul_scalar(0.3),
                        1 => v.softplus(),
                        2 => v.square().add_scalar(0.1),
                        3 => v.sigmoid(),
                        4 => v.sqrt(),
                        _ => v.tanh().add_scalar(1.5),
                    };
                }
                v.sum().item()
            };
            // AD gradient
            let tape = Tape::new();
            let mut v = tape.leaf(Tensor::from_vec(data.clone()));
            let leaf = v.clone();
            for &op in ops {
                v = match op {
                    0 => v.exp().mul_scalar(0.3),
                    1 => v.softplus(),
                    2 => v.square().add_scalar(0.1),
                    3 => v.sigmoid(),
                    4 => v.sqrt(),
                    _ => v.tanh().add_scalar(1.5),
                };
            }
            let g = tape.grad(&v.sum(), &[&leaf]).remove(0);
            // finite differences
            let eps = 1e-6;
            for i in 0..data.len() {
                let mut plus = data.clone();
                plus[i] += eps;
                let mut minus = data.clone();
                minus[i] -= eps;
                let tp = Tape::new();
                let tm = Tape::new();
                let fd = (apply(&tp, Tensor::from_vec(plus)) - apply(&tm, Tensor::from_vec(minus)))
                    / (2.0 * eps);
                testkit::close(g.data()[i], fd, 1e-4)?;
            }
            Ok(())
        },
    );
}

/// The vectorized `plate` (one broadcast site) and the retained
/// sequential `plate_seq` (one site per index) must assign the same
/// scaled log-joint for identical seeds, across random sizes and
/// subsample sizes.
#[test]
fn vectorized_plate_log_joint_matches_sequential() {
    testkit::for_all(
        Config { cases: 40, seed: 0x91A7E5 },
        |rng| {
            let n = 1 + rng.below(24);
            let m = 1 + rng.below(n);
            let data: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            (n, m, data, rng.next_u64())
        },
        |(n, m, data, seed)| {
            let (n, m) = (*n, *m);
            let data_t = Tensor::from_vec(data.clone());
            let dv = data_t.clone();
            let vec_model = move |ctx: &mut Ctx| {
                let mu = ctx.sample("mu", Normal::std(0.0, 10.0));
                ctx.plate("data", n, Some(m), |ctx, plate| {
                    ctx.observe(
                        "x",
                        Normal::new(mu.clone(), ctx.cs(1.0)),
                        plate.select(&dv),
                    );
                });
            };
            let ds = data_t.clone();
            let seq_model = move |ctx: &mut Ctx| {
                let mu = ctx.sample("mu", Normal::std(0.0, 10.0));
                ctx.plate_seq("data", n, Some(m), |ctx, idx| {
                    for &i in idx {
                        ctx.observe(
                            &format!("x_{i}"),
                            Normal::new(mu.clone(), ctx.cs(1.0)),
                            Tensor::scalar(ds.data()[i]),
                        );
                    }
                });
            };
            let mut rng1 = Pcg64::new(*seed);
            let lv = fyro::poutine::trace_fn(&vec_model, &mut rng1).log_prob_sum();
            let mut rng2 = Pcg64::new(*seed);
            let ls = fyro::poutine::trace_fn(&seq_model, &mut rng2).log_prob_sum();
            testkit::close(lv, ls, 1e-10)
        },
    );
}

/// Full ELBO equivalence: a guide/model pair evaluated through
/// `TraceElbo` must produce the same ELBO under the vectorized and
/// sequential plate for identical seeds (fresh stores each side).
#[test]
fn vectorized_plate_elbo_matches_sequential() {
    use fyro::infer::elbo::TraceElbo;
    use fyro::infer::svi::trace_pair;
    testkit::for_all(
        Config { cases: 24, seed: 0xE1B0E5 },
        |rng| {
            let n = 2 + rng.below(16);
            let m = 1 + rng.below(n);
            let data: Vec<f64> = (0..n).map(|_| 0.5 + rng.normal()).collect();
            (n, m, data, rng.next_u64())
        },
        |(n, m, data, seed)| {
            let (n, m) = (*n, *m);
            let data_t = Tensor::from_vec(data.clone());
            let guide = |ctx: &mut Ctx| {
                let loc = ctx.param("mu.loc", || Tensor::scalar(0.1));
                let scale = ctx.param_constrained(
                    "mu.scale",
                    || Tensor::scalar(0.7),
                    Constraint::Positive,
                );
                ctx.sample("mu", Normal::new(loc, scale));
            };
            let run = |vectorized: bool| -> f64 {
                let dt = data_t.clone();
                let vec_model = move |ctx: &mut Ctx| {
                    let mu = ctx.sample("mu", Normal::std(0.0, 10.0));
                    ctx.plate("data", n, Some(m), |ctx, plate| {
                        ctx.observe(
                            "x",
                            Normal::new(mu.clone(), ctx.cs(1.0)),
                            plate.select(&dt),
                        );
                    });
                };
                let dt2 = data_t.clone();
                let seq_model = move |ctx: &mut Ctx| {
                    let mu = ctx.sample("mu", Normal::std(0.0, 10.0));
                    ctx.plate_seq("data", n, Some(m), |ctx, idx| {
                        for &i in idx {
                            ctx.observe(
                                &format!("x_{i}"),
                                Normal::new(mu.clone(), ctx.cs(1.0)),
                                Tensor::scalar(dt2.data()[i]),
                            );
                        }
                    });
                };
                let mut store = ParamStore::new();
                let mut rng = Pcg64::new(*seed);
                let (mt, gt) = if vectorized {
                    trace_pair(&mut store, &mut rng, &vec_model, &guide)
                } else {
                    trace_pair(&mut store, &mut rng, &seq_model, &guide)
                };
                let (_, elbo) = TraceElbo::loss_with_baseline(&mt, &gt, None);
                elbo
            };
            testkit::close(run(true), run(false), 1e-10)
        },
    );
}

/// Nested plates: scales compose multiplicatively and the site's
/// `cond_indep_stack` carries both frames, for random sizes/subsamples.
#[test]
fn nested_plate_scale_composition_property() {
    testkit::for_all(
        Config { cases: 32, seed: 0x2E57ED },
        |rng| {
            let no = 1 + rng.below(8);
            let mo = 1 + rng.below(no);
            let ni = 1 + rng.below(8);
            let mi = 1 + rng.below(ni);
            (no, mo, ni, mi, rng.next_u64())
        },
        |&(no, mo, ni, mi, seed)| {
            let model = move |ctx: &mut Ctx| {
                ctx.plate("o", no, Some(mo), |ctx, po| {
                    let mo_now = po.len();
                    ctx.plate("i", ni, Some(mi), |ctx, pi| {
                        let mi_now = pi.len();
                        ctx.observe(
                            "x",
                            Normal::new(
                                ctx.c(Tensor::zeros(vec![mi_now, mo_now])),
                                ctx.c(Tensor::ones(vec![mi_now, mo_now])),
                            ),
                            Tensor::zeros(vec![mi_now, mo_now]),
                        );
                    });
                });
            };
            let mut rng = Pcg64::new(seed);
            let t = fyro::poutine::trace_fn(&model, &mut rng);
            let s = t.get("x").unwrap();
            let want = (no as f64 / mo as f64) * (ni as f64 / mi as f64);
            testkit::close(s.scale, want, 1e-12)?;
            testkit::ensure(
                s.cond_indep_stack.len() == 2
                    && s.cond_indep_stack[0].name == "i"
                    && s.cond_indep_stack[0].dim == 1
                    && s.cond_indep_stack[1].name == "o"
                    && s.cond_indep_stack[1].dim == 0,
                "cond_indep_stack frames wrong",
            )?;
            // scaled joint == full-population-equivalent of the zeros obs
            let per = -0.5 * fyro::dist::LN_2PI;
            testkit::close(t.log_prob_sum(), (no * ni) as f64 * per, 1e-9)
        },
    );
}

/// Masks apply to the batch-shaped (event-reduced) log-prob: a batch
/// mask over an event-carrying site knocks out whole joint rows.
#[test]
fn mask_broadcasts_over_event_reduced_log_prob() {
    let model = |ctx: &mut Ctx| {
        ctx.observe(
            "x",
            MvNormalDiag::new(
                ctx.c(Tensor::zeros(vec![3, 2])),
                ctx.c(Tensor::ones(vec![3, 2])),
            ),
            Tensor::new(vec![0.0, 0.0, 10.0, 10.0, 0.0, 0.0], vec![3, 2]),
        );
    };
    let masked = fyro::poutine::mask(model, Tensor::from_vec(vec![1.0, 0.0, 1.0]));
    let mut rng = Pcg64::new(1);
    let t = fyro::poutine::trace_fn(&masked, &mut rng);
    // rows 0 and 2 survive: 2 rows x 2 event dims of standard normal at 0
    let per = -0.5 * fyro::dist::LN_2PI;
    assert!((t.log_prob_sum() - 4.0 * per).abs() < 1e-10);
    // the outlier row (masked out) contributes nothing
    let site = t.get("x").unwrap();
    assert_eq!(site.log_prob_batch().value().dims(), &[3]);
}

/// Importance-sampling evidence estimates must be consistent between
/// prior proposals and (imperfect but overlapping) guide proposals.
#[test]
fn evidence_estimates_agree_across_proposals() {
    let model = |ctx: &mut Ctx| {
        let z = ctx.sample("z", Normal::std(0.0, 1.0));
        ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.4));
    };
    let guide = |ctx: &mut Ctx| {
        ctx.sample("z", Normal::std(0.1, 0.9));
    };
    let mut rng = Pcg64::new(99);
    let a = fyro::infer::Importance::from_prior(&model, 30_000, &mut rng).log_evidence();
    let b = fyro::infer::Importance::with_guide(&model, &guide, 30_000, &mut rng)
        .log_evidence();
    let exact = Normal::std(0.0, 2.0f64.sqrt())
        .log_prob(&Tensor::scalar(0.4))
        .item();
    assert!((a - exact).abs() < 0.02, "prior-proposal evidence {a} vs {exact}");
    assert!((b - exact).abs() < 0.02, "guide-proposal evidence {b} vs {exact}");
}
