//! Graph-mode SVI invariants: the compiled straight-line kernel must
//! reproduce the dynamic interpreter's loss and parameter trajectories
//! to 1e-12 on static models (the recording step is *exactly* a dynamic
//! step, so step 0 is identical by construction and every later step
//! pins the fused forward/backward/optimizer chain); guards must trip
//! loudly and fall back to the dynamic path with a diagnosable error;
//! non-compilable estimators must refuse compilation but keep training.

use std::sync::atomic::{AtomicBool, Ordering};

use fyro::infer::svi::{Svi, SviConfig};
use fyro::params::ParamStore;
use fyro::prelude::*;

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-12 * (1.0 + a.abs().max(b.abs()))
}

/// Run the same (model, guide, seed) pair with and without graph mode
/// and require 1e-12 agreement on every per-step loss and every final
/// unconstrained parameter element. Also sanity-checks the diagnostics:
/// one compile, one dynamic (recording) step, the rest compiled.
fn assert_compiled_matches_dynamic(
    base: SviConfig,
    steps: u64,
    model: &(impl Fn(&mut Ctx) + Sync),
    guide: &(impl Fn(&mut Ctx) + Sync),
    params: &[&str],
) {
    let run = |graph_mode: bool| {
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(0xC0FFEE);
        let mut svi = Svi::with_config(
            Adam::new(0.02),
            TraceElbo::default(),
            SviConfig { graph_mode, ..base },
        );
        let losses: Vec<f64> =
            (0..steps).map(|_| svi.step(&mut store, &mut rng, model, guide)).collect();
        let finals: Vec<Vec<f64>> = params
            .iter()
            .map(|p| {
                store
                    .get_unconstrained(p)
                    .unwrap_or_else(|| panic!("param {p} missing"))
                    .data()
                    .to_vec()
            })
            .collect();
        (losses, finals, svi.graph_diagnostics().clone())
    };
    let (l_dyn, p_dyn, _) = run(false);
    let (l_cmp, p_cmp, d) = run(true);
    assert!(d.active, "graph mode did not engage: {:?}", d.last_error);
    assert_eq!(d.compiles, 1, "expected exactly one record->compile->verify pass");
    assert_eq!(d.fallbacks, 0, "unexpected fallback: {:?}", d.last_error);
    assert_eq!(d.dynamic_steps, 1, "only the recording step may run dynamically");
    assert_eq!(d.compiled_steps, steps - 1);
    for (i, (c, r)) in l_cmp.iter().zip(&l_dyn).enumerate() {
        assert!(close(*c, *r), "loss diverged at step {i}: compiled {c} vs dynamic {r}");
    }
    for (name, (pc, pd)) in params.iter().zip(p_cmp.iter().zip(&p_dyn)) {
        assert_eq!(pc.len(), pd.len());
        for (j, (c, r)) in pc.iter().zip(pd).enumerate() {
            assert!(close(*c, *r), "param {name}[{j}] diverged: compiled {c} vs dynamic {r}");
        }
    }
}

/// The conjugate scalar pair used across the infer tests.
fn scalar_model(ctx: &mut Ctx) {
    let z = ctx.sample("z", Normal::std(0.0, 1.0));
    ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.6));
}

fn scalar_guide(ctx: &mut Ctx) {
    let loc = ctx.param("q_loc", || Tensor::scalar(0.0));
    let scale =
        ctx.param_constrained("q_scale", || Tensor::scalar(1.0), Constraint::Positive);
    ctx.sample("z", Normal::new(loc, scale));
}

#[test]
fn compiled_matches_dynamic_scalar_conjugate() {
    assert_compiled_matches_dynamic(
        SviConfig::default(),
        40,
        &scalar_model,
        &scalar_guide,
        &["q_loc", "q_scale"],
    );
}

#[test]
fn compiled_matches_dynamic_subsampled_plate() {
    // latent scalar broadcast over a subsampled vectorized plate: the
    // compiled program must replay the subsample permutation draw and
    // the Select gather/scatter exactly.
    let data_t = Tensor::from_vec((0..16).map(|i| 0.8 + 0.05 * i as f64).collect());
    let n = 16usize;
    let model = move |ctx: &mut Ctx| {
        let mu = ctx.sample("mu", Normal::std(0.0, 5.0));
        ctx.plate("data", n, Some(4), |ctx, plate| {
            ctx.observe(
                "x",
                Normal::new(mu.clone(), ctx.cs(1.0)),
                plate.select(&data_t),
            );
        });
    };
    let guide = |ctx: &mut Ctx| {
        let loc = ctx.param("mu_loc", || Tensor::scalar(0.0));
        let scale =
            ctx.param_constrained("mu_scale", || Tensor::scalar(0.5), Constraint::Positive);
        ctx.sample("mu", Normal::new(loc, scale));
    };
    assert_compiled_matches_dynamic(
        SviConfig::default(),
        30,
        &model,
        &guide,
        &["mu_loc", "mu_scale"],
    );
}

#[test]
fn compiled_matches_dynamic_vector_event_sites() {
    // vector latent with event dims on both sides: MvNormalDiag prior,
    // to_event(1) reparameterized guide, vector observation.
    let obs = Tensor::from_vec(vec![0.4, -1.1, 0.7]);
    let model = move |ctx: &mut Ctx| {
        let z = ctx.sample(
            "z",
            MvNormalDiag::new(ctx.c(Tensor::zeros(vec![3])), ctx.c(Tensor::ones(vec![3]))),
        );
        ctx.observe(
            "y",
            MvNormalDiag::new(z, ctx.c(Tensor::ones(vec![3]).mul_scalar(0.5))),
            obs.clone(),
        );
    };
    let guide = |ctx: &mut Ctx| {
        let loc = ctx.param("z_loc", || Tensor::zeros(vec![3]));
        let scale =
            ctx.param_constrained("z_scale", || Tensor::ones(vec![3]), Constraint::Positive);
        ctx.sample("z", Normal::new(loc, scale).to_event(1));
    };
    assert_compiled_matches_dynamic(
        SviConfig::default(),
        30,
        &model,
        &guide,
        &["z_loc", "z_scale"],
    );
}

#[test]
fn compiled_matches_dynamic_nested_subsampled_plates() {
    // nested subsampled plates: two permutation draws per trace and a
    // product of plate scale factors on the observed site.
    let obs = Tensor::new((0..6).map(|i| 0.3 * i as f64 - 0.8).collect(), vec![2, 3]);
    let model = move |ctx: &mut Ctx| {
        let mu = ctx.sample("mu", Normal::std(0.0, 2.0));
        ctx.plate("outer", 6, Some(3), |ctx, _o| {
            ctx.plate("inner", 10, Some(2), |ctx, _i| {
                // site batch [inner, outer] = [2, 3]
                let loc = ctx.c(Tensor::zeros(vec![2, 3])).add(&mu);
                ctx.observe("x", Normal::new(loc, ctx.cs(1.0)), obs.clone());
            });
        });
    };
    let guide = |ctx: &mut Ctx| {
        let loc = ctx.param("mu_loc", || Tensor::scalar(0.1));
        let scale =
            ctx.param_constrained("mu_scale", || Tensor::scalar(0.7), Constraint::Positive);
        ctx.sample("mu", Normal::new(loc, scale));
    };
    assert_compiled_matches_dynamic(
        SviConfig::default(),
        25,
        &model,
        &guide,
        &["mu_loc", "mu_scale"],
    );
}

#[test]
fn compiled_matches_dynamic_multi_particle() {
    assert_compiled_matches_dynamic(
        SviConfig { num_particles: 4, ..SviConfig::default() },
        25,
        &scalar_model,
        &scalar_guide,
        &["q_loc", "q_scale"],
    );
}

#[test]
fn compiled_matches_dynamic_random_static_models() {
    // property-style sweep: random event dims, observations, and prior
    // scales; every sampled static model must compile and agree.
    let mut meta = Pcg64::new(0x57A71C);
    for case in 0..8 {
        let d = 1 + meta.below(5);
        let obs = Tensor::from_vec((0..d).map(|_| meta.normal()).collect());
        let prior_scale = 0.5 + 2.0 * meta.uniform();
        let noise = 0.3 + meta.uniform();
        let model = {
            let obs = obs.clone();
            move |ctx: &mut Ctx| {
                let z = ctx.sample(
                    "z",
                    MvNormalDiag::new(
                        ctx.c(Tensor::zeros(vec![d])),
                        ctx.c(Tensor::ones(vec![d]).mul_scalar(prior_scale)),
                    ),
                );
                ctx.observe(
                    "y",
                    MvNormalDiag::new(z, ctx.c(Tensor::ones(vec![d]).mul_scalar(noise))),
                    obs.clone(),
                );
            }
        };
        let guide = move |ctx: &mut Ctx| {
            let loc = ctx.param("z_loc", || Tensor::zeros(vec![d]));
            let scale = ctx.param_constrained(
                "z_scale",
                || Tensor::ones(vec![d]),
                Constraint::Positive,
            );
            ctx.sample("z", Normal::new(loc, scale).to_event(1));
        };
        println!("case {case}: d={d} prior_scale={prior_scale:.3} noise={noise:.3}");
        assert_compiled_matches_dynamic(
            SviConfig::default(),
            15,
            &model,
            &guide,
            &["z_loc", "z_scale"],
        );
    }
}

#[test]
fn compiled_parallel_matches_compiled_serial_bitwise() {
    let run = |parallel: bool, threads: usize| {
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(0x9A9A);
        let mut svi = Svi::with_config(
            Adam::new(0.05),
            TraceElbo::default(),
            SviConfig {
                num_particles: 5,
                parallel,
                num_threads: threads,
                graph_mode: true,
                ..SviConfig::default()
            },
        );
        let losses: Vec<f64> = (0..30)
            .map(|_| svi.step(&mut store, &mut rng, &scalar_model, &scalar_guide))
            .collect();
        assert!(svi.graph_diagnostics().active);
        (losses, store.get_unconstrained("q_loc").unwrap().item().to_bits())
    };
    let (l_serial, loc_serial) = run(false, 0);
    for threads in [2usize, 3, 5] {
        let (l_par, loc_par) = run(true, threads);
        assert_eq!(l_serial, l_par, "compiled trajectory diverged at {threads} threads");
        assert_eq!(loc_serial, loc_par);
    }
}

#[test]
fn structure_change_trips_revalidation_guard() {
    // a control-flow change the per-step fingerprint CANNOT see (no new
    // params): only the scheduled full re-trace catches it, falls back
    // loudly with a site-level diff, and recompiles the new structure.
    let grow = AtomicBool::new(false);
    let model = |ctx: &mut Ctx| {
        let z = ctx.sample("z", Normal::std(0.0, 1.0));
        if grow.load(Ordering::Relaxed) {
            ctx.sample("extra_site", Normal::std(0.0, 1.0));
        }
        ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.6));
    };
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(0xFEED);
    let mut svi = Svi::with_config(
        Adam::new(0.02),
        TraceElbo::default(),
        SviConfig { graph_mode: true, graph_revalidate: 1, ..SviConfig::default() },
    );
    for _ in 0..4 {
        let loss = svi.step(&mut store, &mut rng, &model, &scalar_guide);
        assert!(loss.is_finite());
    }
    assert!(svi.graph_diagnostics().active);
    assert_eq!(svi.graph_diagnostics().fallbacks, 0);
    grow.store(true, Ordering::Relaxed);
    for _ in 0..4 {
        let loss = svi.step(&mut store, &mut rng, &model, &scalar_guide);
        assert!(loss.is_finite());
    }
    let d = svi.graph_diagnostics();
    assert!(d.fallbacks >= 1, "structure change was never detected");
    let diff = d
        .last_structure_diff
        .as_deref()
        .expect("fallback must record a site-level structure diff");
    assert!(
        diff.contains("extra_site"),
        "diff must name the site that appeared, got: {diff}"
    );
    assert!(d.active, "graph mode must recompile the new structure and re-engage");
    assert!(d.compiles >= 2);
}

#[test]
fn param_store_change_trips_fingerprint_guard() {
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(0xBEEF);
    let mut svi = Svi::with_config(
        Adam::new(0.02),
        TraceElbo::default(),
        SviConfig { graph_mode: true, ..SviConfig::default() },
    );
    for _ in 0..3 {
        svi.step(&mut store, &mut rng, &scalar_model, &scalar_guide);
    }
    assert!(svi.graph_diagnostics().active);
    // an out-of-band param (e.g. another model sharing the store)
    // changes the store fingerprint; the cheap per-step guard must trip
    store.get_or_init("out_of_band", || Tensor::scalar(0.0), Constraint::Real);
    for _ in 0..3 {
        let loss = svi.step(&mut store, &mut rng, &scalar_model, &scalar_guide);
        assert!(loss.is_finite());
    }
    let d = svi.graph_diagnostics();
    assert_eq!(d.fallbacks, 1, "fingerprint guard must trip exactly once");
    assert!(
        d.last_error.as_deref().unwrap_or("").contains("parameter store changed shape"),
        "fallback reason must be diagnosable, got: {:?}",
        d.last_error
    );
    assert!(d.active, "graph mode must recompile against the grown store");
    assert_eq!(d.compiles, 2);
}

#[test]
fn non_compilable_estimator_disables_graph_mode_but_keeps_training() {
    let run = |graph_mode: bool| {
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(0xD15C);
        let mut svi = Svi::with_config(
            Adam::new(0.02),
            TraceGraphElbo::default(),
            SviConfig { graph_mode, ..SviConfig::default() },
        );
        let losses: Vec<f64> = (0..10)
            .map(|_| svi.step(&mut store, &mut rng, &scalar_model, &scalar_guide))
            .collect();
        (losses, svi.graph_diagnostics().clone())
    };
    let (l_plain, _) = run(false);
    let (l_graph, d) = run(true);
    assert!(!d.active, "TraceGraph must not compile");
    assert_eq!(d.compiled_steps, 0);
    assert_eq!(d.compiles, 0);
    assert!(
        d.last_error.as_deref().unwrap_or("").contains("not compilable"),
        "disable reason must name the estimator problem, got: {:?}",
        d.last_error
    );
    // disabling must not perturb the dynamic path: identical trajectory
    assert_eq!(l_plain, l_graph);

    // the eager API surfaces the same refusal as an error
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(1);
    let mut svi = Svi::new(Adam::new(0.02), TraceGraphElbo::default());
    let err = svi
        .compile(&mut store, &mut rng, &scalar_model, &scalar_guide)
        .expect_err("compile() must refuse a non-compilable estimator");
    assert!(err.to_string().contains("not compilable"));
}

#[test]
fn eager_compile_then_all_steps_run_compiled() {
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(0xACE);
    let mut svi = Svi::new(Adam::new(0.02), TraceMeanFieldElbo::default());
    svi.compile(&mut store, &mut rng, &scalar_model, &scalar_guide)
        .expect("static model must compile eagerly");
    let d = svi.graph_diagnostics();
    assert!(d.active);
    assert_eq!(d.compiles, 1);
    for _ in 0..10 {
        let loss = svi.step(&mut store, &mut rng, &scalar_model, &scalar_guide);
        assert!(loss.is_finite());
    }
    let d = svi.graph_diagnostics();
    assert_eq!(d.compiled_steps, 10, "every post-compile step must run compiled");
    assert_eq!(d.fallbacks, 0);
}
