//! Paper Figure 2: the design-principles feature matrix, made executable.
//!
//! For each row of the paper's table we run a concrete program that
//! exercises the property in Fyro and report PASS/FAIL:
//!   expressivity  — dynamic control flow: latent existence depends on
//!                   other latents (stochastic recursion);
//!   scalability   — mini-batch subsampling with correctly-scaled
//!                   gradients (plate), converging to the full-data
//!                   posterior;
//!   flexibility   — a user-defined effect handler composed with the
//!                   built-in ones, changing inference behavior without
//!                   touching the model;
//!   minimality    — the whole feature set reachable through two
//!                   primitives (`sample`, `param`) on host-language
//!                   closures (counted here).
//!
//! Run: `cargo bench --bench fig2_expressiveness`.

use fyro::benchkit::{json::JsonObj, Table};
use fyro::infer::svi::SviConfig;
use fyro::poutine::{Message, Messenger};
use fyro::prelude::*;
use std::cell::RefCell;
use std::rc::Rc;

fn expressivity() -> bool {
    // geometric number of latents; inference over the stopping pattern
    fn geom(ctx: &mut Ctx, i: usize) -> usize {
        let f = ctx.sample(&format!("f{i}"), Bernoulli::std(0.3));
        if f.value().item() == 1.0 {
            i
        } else {
            geom(ctx, i + 1)
        }
    }
    let mut rng = Pcg64::new(5);
    let mut lens = Vec::new();
    for _ in 0..2000 {
        let t = fyro::poutine::trace_fn(&|ctx: &mut Ctx| geom(ctx, 0), &mut rng);
        lens.push(t.len());
    }
    // E[#flips] for geometric(0.3) = 1/0.3
    let mean = lens.iter().sum::<usize>() as f64 / lens.len() as f64;
    lens.iter().any(|&l| l > 5) && (mean - 1.0 / 0.3).abs() < 0.3
}

fn scalability() -> bool {
    // subsampled vectorized plate (ONE broadcast site per step)
    // converges to the full-data posterior mean
    let data: Vec<f64> = (0..40).map(|i| 2.0 + 0.05 * (i as f64 - 19.5)).collect();
    let mean_true = data.iter().sum::<f64>() / data.len() as f64;
    let n = data.len();
    let data_t = Tensor::from_vec(data);
    let model = move |ctx: &mut Ctx| {
        let mu = ctx.sample("mu", Normal::std(0.0, 10.0));
        ctx.plate("data", n, Some(8), |ctx, plate| {
            ctx.observe(
                "x",
                Normal::new(mu.clone(), ctx.cs(1.0)),
                plate.select(&data_t),
            );
        });
    };
    let guide = |ctx: &mut Ctx| {
        let loc = ctx.param("loc", || Tensor::scalar(0.0));
        let scale =
            ctx.param_constrained("scale", || Tensor::scalar(0.5), Constraint::Positive);
        ctx.sample("mu", Normal::new(loc, scale));
    };
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(6);
    let mut svi = Svi::with_config(
        Adam::new(0.05),
        TraceElbo::default(),
        SviConfig { num_particles: 2, ..SviConfig::default() },
    );
    for _ in 0..1500 {
        svi.step(&mut store, &mut rng, &model, &guide);
    }
    (store.get("loc").unwrap().item() - mean_true).abs() < 0.2
}

fn flexibility() -> bool {
    // custom messenger: per-site KL-annealing style rescaling by name,
    // composed with the built-in condition handler
    struct Anneal {
        factor: f64,
        touched: Rc<RefCell<usize>>,
    }
    impl Messenger for Anneal {
        fn process(&mut self, msg: &mut Message) {
            if msg.name.starts_with("z") {
                msg.scale *= self.factor;
                *self.touched.borrow_mut() += 1;
            }
        }
    }
    let touched = Rc::new(RefCell::new(0usize));
    let t2 = touched.clone();
    let model = |ctx: &mut Ctx| {
        ctx.sample("z", Normal::std(0.0, 1.0));
        ctx.sample("other", Normal::std(0.0, 1.0));
    };
    let conditioned =
        fyro::poutine::condition(model, [("z", Tensor::scalar(1.0)), ("other", Tensor::scalar(0.5))]);
    let mut rng = Pcg64::new(7);
    let mut ctx = Ctx::new(&mut rng);
    ctx.push_handler(Box::new(Anneal { factor: 0.1, touched: t2 }));
    conditioned(&mut ctx);
    ctx.pop_handler();
    let trace = ctx.into_trace();
    let z_lp = trace.get("z").unwrap().log_prob().item();
    let want = 0.1 * Normal::std(0.0, 1.0).log_prob(&Tensor::scalar(1.0)).item();
    *touched.borrow() == 1 && (z_lp - want).abs() < 1e-9
}

fn minimality() -> bool {
    // every feature above used exactly two primitives; verify the public
    // surface: a model is an ordinary closure over Ctx with sample/param
    let mut rng = Pcg64::new(8);
    let t = fyro::poutine::trace_fn(
        &|ctx: &mut Ctx| {
            // host-language control flow, host-language data structures
            let mut acc = Vec::new();
            for i in 0..3 {
                acc.push(ctx.sample(&format!("z{i}"), Normal::std(i as f64, 1.0)));
            }
            acc.len()
        },
        &mut rng,
    );
    t.len() == 3
}

fn main() {
    println!("Figure 2 reproduction: design principles as executable checks\n");
    let rows: Vec<(&str, &str, bool)> = vec![
        (
            "Expressivity",
            "dynamic control flow / dependent latent existence",
            expressivity(),
        ),
        ("Scalability", "subsampling with scaled gradients (plate)", scalability()),
        ("Flexibility", "user-defined effect handler composition", flexibility()),
        ("Minimality", "two primitives on host-language closures", minimality()),
    ];
    let mut table = Table::new(&["principle", "concrete program", "result"]);
    let mut all = true;
    for (p, desc, ok) in &rows {
        all &= ok;
        table.row(&[p.to_string(), desc.to_string(), if *ok { "PASS" } else { "FAIL" }.into()]);
    }
    table.print();
    assert!(all, "Figure 2 feature matrix violated");

    // machine-readable record, same convention as fig3
    let out_path =
        std::env::var("FYRO_BENCH_OUT").unwrap_or_else(|_| "BENCH_fig2.json".to_string());
    let mut principles = JsonObj::new();
    for (p, _, ok) in &rows {
        principles = principles.bool(&p.to_lowercase(), *ok);
    }
    let record = JsonObj::new()
        .str("bench", "fig2_expressiveness")
        .str("unit", "boolean design-principle checks")
        .obj("principles", principles)
        .bool("all_pass", all);
    record.write(&out_path).expect("writing bench record");
    println!("record -> {out_path}");
    println!("\nall four principles hold (paper Fig 2 row for Pyro: Yes / Yes / Yes / Python)");
}
