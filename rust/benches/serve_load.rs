//! Serving-layer heavy-traffic bench: requests/sec and p50/p95/p99
//! latency for batched posterior queries over the frozen model zoo
//! (vae v1, gmm v1+v2, eight_schools v1) at 1..N workers, plus the
//! batched-vs-unbatched dispatch comparison, solo-vs-batched bitwise
//! parity, compiled-vs-dynamic Score parity at 1e-12, and the
//! overload/backpressure exercise.
//!
//! The interesting work lives in `fyro::serve::loadgen::run_bench`,
//! shared with the `fyro serve-bench` CLI subcommand; this harness only
//! reads the env knobs and writes the record.
//!
//! Output: a machine-readable record at `$FYRO_BENCH_OUT` (default
//! `BENCH_serve.json`).
//!
//! Knobs: FYRO_BENCH_SMOKE=1 (32 clients x 4 requests, W in {1, 2} —
//! the CI smoke; the full run drives 1024 clients x 20 requests at
//! W in {1, 2, 4}).
//!
//! Run: `cargo bench --bench serve_load`.

use fyro::serve::loadgen;

fn main() {
    let smoke = std::env::var("FYRO_BENCH_SMOKE").is_ok();
    let out =
        std::env::var("FYRO_BENCH_OUT").unwrap_or_else(|_| "BENCH_serve.json".to_string());
    let record = loadgen::run_bench(smoke);
    record.write(&out).expect("write bench record");
    println!("{}", record.render());
    println!("wrote {out}");
}
