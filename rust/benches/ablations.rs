//! Ablation benches for design choices DESIGN.md calls out:
//!
//!   A. MC-KL (`Trace_ELBO`) vs analytic-KL (`TraceMeanField_ELBO`) —
//!      the paper notes its models use MC estimates of the KL terms;
//!      this measures the gradient-variance price of that choice.
//!   B. Adam vs ClippedAdam on the same SVI problem — Pyro ships
//!      ClippedAdam specifically for DMM-style training.
//!   C. NUTS vs fixed-length HMC — effective samples per gradient eval
//!      on a correlated posterior.
//!
//! Run: `cargo bench --bench ablations`.

use fyro::benchkit::Table;
use fyro::infer::mcmc::{Hmc, McmcConfig, Nuts};
use fyro::infer::svi::SviConfig;
use fyro::prelude::*;

fn model(ctx: &mut Ctx) {
    let z = ctx.sample("z", Normal::std(0.0, 1.0));
    ctx.observe("x", Normal::new(z, ctx.cs(1.0)), Tensor::scalar(0.6));
}

fn guide(ctx: &mut Ctx) {
    let loc = ctx.param("loc", || Tensor::scalar(0.0));
    let scale = ctx.param_constrained("scale", || Tensor::scalar(1.0), Constraint::Positive);
    ctx.sample("z", Normal::new(loc, scale));
}

/// A: variance of the loss estimate at a fixed parameter point, with
/// the loss selected at runtime as a `Box<dyn Elbo>` estimator object.
/// The guide must differ from the prior: at q == p the MC-KL term is
/// pointwise zero and the two estimators coincide exactly.
fn ablation_kl() {
    println!("A. ELBO estimator std at two fixed guides (2000 evaluations each)\n");
    let mut table = Table::new(&["estimator", "guide", "mean loss", "loss std"]);
    let guides: [(&str, f64, f64); 2] =
        [("near posterior N(.25,.7)", 0.25, 0.7), ("far N(-1.5,.3)", -1.5, 0.3)];
    for (gl, gloc, gscale) in guides {
        let estimators: [(Box<dyn Elbo>, &str); 2] = [
            (Box::new(TraceElbo::default()), "MC-KL Trace_ELBO"),
            (Box::new(TraceMeanFieldElbo), "analytic TraceMeanField"),
        ];
        for (elbo, label) in estimators {
            let fixed_guide = move |ctx: &mut Ctx| {
                ctx.sample("z", Normal::std(gloc, gscale));
            };
            let mut store = ParamStore::new();
            let mut rng = Pcg64::new(3);
            let svi = Svi::with_config(
                Adam::new(0.0),
                elbo,
                SviConfig { num_particles: 1, ..SviConfig::default() },
            );
            let losses: Vec<f64> = (0..2000)
                .map(|_| svi.evaluate_loss(&mut store, &mut rng, &model, &fixed_guide))
                .collect();
            let mean = losses.iter().sum::<f64>() / losses.len() as f64;
            let var = losses.iter().map(|l| (l - mean) * (l - mean)).sum::<f64>()
                / losses.len() as f64;
            table.row(&[
                label.to_string(),
                gl.to_string(),
                format!("{mean:.4}"),
                format!("{:.4}", var.sqrt()),
            ]);
        }
    }
    table.print();
    println!(
        "\nnote: near the optimum the MC-KL estimator's two terms cancel\n\
         (variance -> 0 at q = posterior) while the analytic form keeps the\n\
         E_q[log lik] noise; far from it, the analytic KL removes variance."
    );
}

/// B: optimizer comparison on a spiky-gradient problem (outlier obs,
/// single particle, hot lr) — the regime ClippedAdam exists for.
fn ablation_optimizer() {
    println!("\nB. Adam vs ClippedAdam on a heavy-tailed problem (5 seeds, 800 steps)\n");
    let spiky_model = |ctx: &mut Ctx| {
        let z = ctx.sample("z", Normal::std(0.0, 1.0));
        // small-scale likelihood: wrong z gives huge gradients
        ctx.observe("x", Normal::new(z, ctx.cs(0.05)), Tensor::scalar(0.8));
    };
    let mut table = Table::new(&["optimizer", "final loc err (avg)", "worst seed err", "diverged"]);
    let run = |clipped: bool| -> (f64, f64, usize) {
        let (mut err_acc, mut worst, mut diverged) = (0.0, 0.0f64, 0usize);
        for seed in 0..5u64 {
            let mut store = ParamStore::new();
            let mut rng = Pcg64::new(seed);
            let cfg = SviConfig { num_particles: 1, ..SviConfig::default() };
            if clipped {
                let mut svi =
                    Svi::with_config(ClippedAdam::new(0.1, 2.0, 0.999), TraceElbo::default(), cfg);
                for _ in 0..800 {
                    svi.step(&mut store, &mut rng, &spiky_model, &guide);
                }
            } else {
                let mut svi = Svi::with_config(Adam::new(0.1), TraceElbo::default(), cfg);
                for _ in 0..800 {
                    svi.step(&mut store, &mut rng, &spiky_model, &guide);
                }
            }
            let err = (store.get("loc").unwrap().item() - 0.8).abs();
            if !err.is_finite() || err > 0.5 {
                diverged += 1;
            }
            err_acc += err.min(10.0);
            worst = worst.max(err.min(10.0));
        }
        (err_acc / 5.0, worst, diverged)
    };
    let (e_adam, w_adam, d_adam) = run(false);
    let (e_clip, w_clip, d_clip) = run(true);
    table.row(&["Adam".into(), format!("{e_adam:.3}"), format!("{w_adam:.3}"), d_adam.to_string()]);
    table.row(&["ClippedAdam".into(), format!("{e_clip:.3}"), format!("{w_clip:.3}"), d_clip.to_string()]);
    table.print();
}

/// C: NUTS vs HMC on a correlated ("banana-lite") posterior.
fn ablation_mcmc() {
    println!("\nC. NUTS vs HMC on a correlated 2-D posterior (700 samples)\n");
    let corr_model = |ctx: &mut Ctx| {
        let z1 = ctx.sample("z1", Normal::std(0.0, 1.0));
        ctx.sample("z2", Normal::new(z1.mul_scalar(0.95), ctx.cs(0.3)));
    };
    let mut table = Table::new(&["sampler", "accept", "z1 mean err", "z2 std err", "tree depth"]);
    let cfg = McmcConfig { warmup: 300, samples: 700, seed: 12, ..Default::default() };
    let h = Hmc::run(&corr_model, cfg);
    let n = Nuts::run(&corr_model, cfg);
    let z2_std_true = (0.95f64 * 0.95 + 0.09).sqrt();
    for (name, out) in [("HMC(L~16)", &h), ("NUTS", &n)] {
        table.row(&[
            name.to_string(),
            format!("{:.2}", out.accept_rate),
            format!("{:.3}", out.mean("z1").item().abs()),
            format!("{:.3}", (out.std("z2").item() - z2_std_true).abs()),
            format!("{:.1}", out.mean_tree_depth),
        ]);
    }
    table.print();
}

fn main() {
    println!("Ablation benches (DESIGN.md §6 design choices)\n");
    ablation_kl();
    ablation_optimizer();
    ablation_mcmc();
    println!("\nablations done");
}
