//! Paper Figure 4: DMM test ELBO with 0/1/2 IAF-extended guides.
//!
//! Paper's numbers (JSB chorales, 5000 epochs, nats/timestep):
//!   0 IAF (theirs) -6.93 | 0 IAF (ours) -6.87 | 1 IAF -6.82 | 2 IAF -6.80
//! Expected *shape* on synthetic chorales at CPU budget: test ELBO
//! improves monotonically as IAF flows are added (absolute scale differs
//! — different corpus, far fewer epochs).
//!
//! Run: `cargo bench --bench fig4_dmm_elbo` (after `make artifacts`).
//! Budget knobs: FYRO_BENCH_EPOCHS (default 12), FYRO_BENCH_SEQS (256).

use fyro::benchkit::Table;
use fyro::coordinator::DmmTrainer;
use fyro::runtime::ArtifactCache;

fn main() -> fyro::error::Result<()> {
    let epochs: usize = std::env::var("FYRO_BENCH_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12);
    let n_train: usize = std::env::var("FYRO_BENCH_SEQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    let cache = match ArtifactCache::open("artifacts") {
        Ok(c) => c,
        Err(e) => {
            println!("skipping: compiled-path artifacts unavailable ({e})");
            return Ok(());
        }
    };

    println!("Figure 4 reproduction: DMM test ELBO vs number of IAF flows");
    println!("(synthetic chorales, {n_train} train seqs, {epochs} epochs each)\n");

    let paper = [(-6.87, "0 (ours)"), (-6.82, "1"), (-6.80, "2")];
    let mut results = Vec::new();
    for k in 0..3usize {
        let name = format!("dmm_iaf{k}");
        println!("training {name} ...");
        let model = match cache.load(&name) {
            Ok(m) => m,
            Err(e) => {
                println!("skipping: compiled-path backend unavailable ({e})");
                return Ok(());
            }
        };
        let mut trainer = DmmTrainer::new(model, n_train, 64)?;
        let mut last = f64::NAN;
        for e in 0..epochs {
            let s = trainer.run_epoch(e)?;
            last = s.test_loss;
            if e % 4 == 3 {
                println!("  epoch {e:>3}: test -ELBO/t {last:.4}");
            }
        }
        results.push(-last); // report ELBO (higher is better), like the paper
    }

    let mut table = Table::new(&["# IAFs", "test ELBO (ours)", "paper"]);
    for (elbo, (paper_elbo, label)) in results.iter().zip(paper) {
        table.row(&[
            format!("{label}"),
            format!("{elbo:.4}"),
            format!("{paper_elbo:.2}"),
        ]);
    }
    table.print();

    let monotone = results.windows(2).all(|w| w[1] >= w[0] - 0.02);
    println!(
        "\nshape check (ELBO improves with flows): {}",
        if monotone { "HOLDS" } else { "VIOLATED — increase FYRO_BENCH_EPOCHS" }
    );
    Ok(())
}
