//! Paper Figure 4 workload (the deep Markov model), data-parallel
//! edition: ELBO throughput of sharded SVI over a DMM as workers are
//! added, the determinism guarantees that make the parallel numbers
//! trustworthy, and the async parameter-server row.
//!
//! Sections:
//! 1. **Allocation-free epoch loop** — the steady-state data path
//!    (`ShardCursor::next_batch` + `ShardedLoader::gather_into`, and the
//!    `BatchIter::next_into` / `gather_images_into` variants) must not
//!    allocate, asserted via the counting-allocator proxy.
//! 2. **Throughput sweep** — synchronous `DataParallelSvi` over the DMM
//!    at W ∈ {1, 2, 4, 8} shards, serial vs scoped-thread evaluation;
//!    rows/sec and the thread-speedup per W.
//! 3. **Determinism** — at fixed W=2 shards, threaded evaluation must
//!    match serial evaluation **bitwise** (losses and final params), and
//!    graph-mode (compile once, per-worker arenas) must match the
//!    dynamic interpreter to 1e-12 while staying thread-invariant.
//! 4. **Streaming** — the same sweep model fed from an on-disk
//!    `StreamLoader` must reproduce the in-memory `MemLoader` losses
//!    bitwise (the loader is outside the semantics).
//! 5. **Async** — `coordinator::train_async` on the same model/loader,
//!    reporting applied/rejected pushes and throughput.
//!
//! Output: a human table on stdout plus a machine-readable record at
//! `$FYRO_BENCH_OUT` (default `BENCH_fig4.json`).
//!
//! Knobs: FYRO_BENCH_ITERS (default 30), FYRO_BENCH_SMOKE=1 (tiny dims,
//! W ∈ {1, 2}, for the CI smoke).
//!
//! Run: `cargo bench --bench fig4_dmm_elbo`.

use fyro::benchkit::{self, json::JsonObj, Table};
use fyro::coordinator::{train_async, AsyncConfig, ParamServer};
use fyro::data::{gather_images_into, BatchIter, MemLoader, ShardCursor, StreamLoader};
use fyro::infer::{BatchLayout, DataParallelSvi, ShardBatch, ShardConfig};
use fyro::nn::Linear;
use fyro::params::ParamStore;
use fyro::poutine::Ctx;
use fyro::prelude::*;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

// ------------------------------------------------- allocations proxy

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

// ------------------------------------------------------ configuration

#[derive(Clone, Copy)]
struct Cfg {
    t: usize,
    zd: usize,
    xd: usize,
    batch: usize,
    rows: usize,
    iters: usize,
    warmup: usize,
    smoke: bool,
}

impl Cfg {
    fn from_env() -> Cfg {
        let smoke = std::env::var("FYRO_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
        let iters: usize = std::env::var("FYRO_BENCH_ITERS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(if smoke { 4 } else { 30 });
        if smoke {
            Cfg { t: 3, zd: 3, xd: 16, batch: 8, rows: 192, iters, warmup: 1, smoke }
        } else {
            Cfg { t: 8, zd: 8, xd: 88, batch: 16, rows: 1024, iters, warmup: 3, smoke }
        }
    }

    fn worker_counts(&self) -> Vec<usize> {
        if self.smoke {
            vec![1, 2]
        } else {
            vec![1, 2, 4, 8]
        }
    }
}

/// Synthetic piano rolls: `[rows][T][xd]` Bernoulli frames.
fn make_rolls(cfg: &Cfg) -> Vec<Vec<Vec<f32>>> {
    let mut rng = Pcg64::new(0xD33);
    (0..cfg.rows)
        .map(|_| {
            (0..cfg.t)
                .map(|_| (0..cfg.xd).map(|_| f32::from(rng.uniform() < 0.3)).collect())
                .collect()
        })
        .collect()
}

// ---------------------------------------------------------- the DMM

/// model: z_0 ~ N(0, I); z_t ~ N(W z_{t-1}, I); x_t ~ Bern(emit(z_t)),
/// all inside one index-subsampled batch plate. Each frame view goes on
/// the tape directly via `observe` (the graph-mode data contract).
fn make_dmm_model(cfg: &Cfg) -> impl Fn(&mut Ctx, &ShardBatch) + Sync {
    let (t_len, zd, xd) = (cfg.t, cfg.zd, cfg.xd);
    move |ctx: &mut Ctx, b: &ShardBatch| {
        let batch = b.idx.len();
        ctx.plate_idx("batch", b.total, b.idx, |ctx, _plate| {
            let trans = Linear::new("m.trans", zd, zd);
            let emit = Linear::new("m.emit", zd, xd);
            let ones = ctx.c(Tensor::ones(vec![batch, zd]));
            let mut z_prev: Option<Var> = None;
            for t in 0..t_len {
                let loc = match &z_prev {
                    None => ctx.c(Tensor::zeros(vec![batch, zd])),
                    Some(z) => trans.forward(ctx, z),
                };
                let z = ctx.sample(&format!("z_{t}"), MvNormalDiag::new(loc, ones.clone()));
                let logits = emit.forward(ctx, &z);
                ctx.observe(
                    &format!("x_{t}"),
                    Bernoulli::new(logits).to_event(1),
                    b.views[t].clone(),
                );
                z_prev = Some(z);
            }
        });
    }
}

/// guide: z_t ~ N(enc(x_t) + trans(z_{t-1}), softplus-ish scale) — a
/// structured mean-field guide conditioned on the frame and the
/// previous latent, fully reparameterized (TraceElbo-compilable).
fn make_dmm_guide(cfg: &Cfg) -> impl Fn(&mut Ctx, &ShardBatch) + Sync {
    let (t_len, zd, xd) = (cfg.t, cfg.zd, cfg.xd);
    move |ctx: &mut Ctx, b: &ShardBatch| {
        let enc = Linear::new("g.enc", xd, zd);
        let trans = Linear::new("g.trans", zd, zd);
        let head_ls = Linear::new("g.ls", xd, zd);
        let mut z_prev: Option<Var> = None;
        for t in 0..t_len {
            let xv = ctx.c(b.views[t].clone());
            let mut loc = enc.forward(ctx, &xv);
            if let Some(z) = &z_prev {
                loc = loc.add(&trans.forward(ctx, z));
            }
            let scale = head_ls.forward(ctx, &xv).mul_scalar(0.25).exp();
            let z = ctx.sample(&format!("z_{t}"), MvNormalDiag::new(loc, scale));
            z_prev = Some(z);
        }
    }
}

// ------------------------------------------------------- measurement

fn measure(
    label: &str,
    warmup: usize,
    iters: usize,
    mut f: impl FnMut(),
) -> (benchkit::Timing, f64) {
    for _ in 0..warmup {
        f();
    }
    let a0 = alloc_count();
    let t = benchkit::bench(label, 0, iters, f);
    let allocs = (alloc_count() - a0) as f64 / iters.max(1) as f64;
    (t, allocs)
}

fn shard_config(cfg: &Cfg, w: usize, parallel: bool, graph: bool) -> ShardConfig {
    ShardConfig {
        num_shards: w,
        batch: cfg.batch,
        parallel,
        num_threads: if parallel { w } else { 1 },
        graph_mode: graph,
        ..ShardConfig::new(w, cfg.batch)
    }
}

fn dp_step_loop(
    cfg: &Cfg,
    loader: &MemLoader,
    layout: &BatchLayout,
    sc: ShardConfig,
    label: &str,
) -> benchkit::Timing {
    let model = make_dmm_model(cfg);
    let guide = make_dmm_guide(cfg);
    let mut dp = DataParallelSvi::new(Adam::new(0.003), TraceElbo::default(), sc, layout.clone());
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(7);
    let (t, _) = measure(label, cfg.warmup, cfg.iters, || {
        std::hint::black_box(
            dp.step(&mut store, &mut rng, loader, &model, &guide).expect("dp step"),
        );
    });
    t
}

/// Loss trajectory + final params under a given shard config (the
/// determinism checks). Params come back name-sorted.
fn dp_trajectory(
    cfg: &Cfg,
    loader: &dyn fyro::data::ShardedLoader,
    layout: &BatchLayout,
    sc: ShardConfig,
    steps: usize,
) -> (Vec<f64>, Vec<(String, Vec<f64>)>, fyro::infer::GraphDiagnostics) {
    let model = make_dmm_model(cfg);
    let guide = make_dmm_guide(cfg);
    let mut dp = DataParallelSvi::new(Adam::new(0.003), TraceElbo::default(), sc, layout.clone());
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(21);
    let losses: Vec<f64> = (0..steps)
        .map(|_| dp.step(&mut store, &mut rng, loader, &model, &guide).expect("dp step"))
        .collect();
    let params: Vec<(String, Vec<f64>)> = store
        .names()
        .into_iter()
        .map(|n| {
            let v = store.get(&n).expect("named param").data().to_vec();
            (n, v)
        })
        .collect();
    (losses, params, dp.graph_diagnostics().clone())
}

fn main() {
    let cfg = Cfg::from_env();
    let hw_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "Figure 4 workload (DMM), data-parallel SVI: throughput vs workers\n\
         (T={}, z={}, x={}, batch/shard={}, rows={}, {} iters{}; {hw_threads} cores)\n",
        cfg.t,
        cfg.zd,
        cfg.xd,
        cfg.batch,
        cfg.rows,
        cfg.iters,
        if cfg.smoke { ", SMOKE" } else { "" },
    );

    let rolls = make_rolls(&cfg);
    let loader = MemLoader::from_rolls(&rolls);
    let layout = BatchLayout::frames(cfg.t, &[cfg.xd]);
    let row_numel = cfg.t * cfg.xd;

    // ---- 1. the steady-state epoch data loop must not allocate ----
    let data_loop_allocs = {
        let mut cursor = ShardCursor::for_shard(&loader, 2, 0, cfg.batch, 0xA110C);
        let mut scratch: Vec<f32> = Vec::with_capacity(cfg.batch * row_numel);
        let per_epoch = cursor.batches_per_epoch();
        // warm one full epoch so every buffer is at capacity and the
        // epoch-boundary reshuffle has run once
        for _ in 0..per_epoch + 1 {
            let idx = cursor.next_batch();
            loader.gather_into(idx, &mut scratch).expect("gather");
        }
        let a0 = alloc_count();
        for _ in 0..per_epoch + 1 {
            let idx = cursor.next_batch();
            loader.gather_into(idx, &mut scratch).expect("gather");
            std::hint::black_box(scratch.len());
        }
        let cursor_allocs = alloc_count() - a0;

        // the `_into` BatchIter/gather variants, same discipline
        let images: Vec<Vec<f32>> = rolls
            .iter()
            .map(|r| r.iter().flatten().copied().collect())
            .collect();
        let mut rng = Pcg64::new(0xBA7C);
        let mut it = BatchIter::new(images.len(), cfg.batch, &mut rng);
        let mut idxbuf: Vec<usize> = Vec::with_capacity(cfg.batch);
        let mut out: Vec<f32> = Vec::with_capacity(cfg.batch * row_numel);
        while it.next_into(&mut idxbuf) {
            gather_images_into(&images, &idxbuf, &mut out);
        }
        it.reset(&mut rng);
        let a0 = alloc_count();
        while it.next_into(&mut idxbuf) {
            gather_images_into(&images, &idxbuf, &mut out);
            std::hint::black_box(out.len());
        }
        let iter_allocs = alloc_count() - a0;
        println!(
            "epoch data loop allocations: shard-cursor {cursor_allocs}, batch-iter {iter_allocs}"
        );
        assert_eq!(cursor_allocs, 0, "ShardCursor epoch loop allocated");
        assert_eq!(iter_allocs, 0, "BatchIter _into epoch loop allocated");
        cursor_allocs + iter_allocs
    };

    // ---- 2. throughput sweep over worker counts ----
    let mut sweep_rows = Vec::new();
    let mut table =
        Table::new(&["workers", "ns/step serial", "ns/step threaded", "speedup", "rows/sec"]);
    let mut speedup_w2 = f64::NAN;
    for &w in &cfg.worker_counts() {
        let t_serial =
            dp_step_loop(&cfg, &loader, &layout, shard_config(&cfg, w, false, false), "serial");
        let t_par =
            dp_step_loop(&cfg, &loader, &layout, shard_config(&cfg, w, true, false), "threaded");
        let speedup = t_serial.ns_per_iter() / t_par.ns_per_iter();
        let rows_per_sec = (w * cfg.batch) as f64 * 1e9 / t_par.ns_per_iter();
        if w == 2 {
            speedup_w2 = speedup;
        }
        table.row(&[
            w.to_string(),
            format!("{:.0}", t_serial.ns_per_iter()),
            format!("{:.0}", t_par.ns_per_iter()),
            format!("{speedup:.2}x"),
            format!("{rows_per_sec:.0}"),
        ]);
        sweep_rows.push(
            JsonObj::new()
                .int("workers", w)
                .num("ns_per_step_serial", t_serial.ns_per_iter())
                .num("ns_per_step_threaded", t_par.ns_per_iter())
                .num("thread_speedup", speedup)
                .num("rows_per_sec", rows_per_sec),
        );
    }
    table.print();

    // ---- 3a. W threads == 1 thread, bitwise, at fixed shards ----
    let det_steps = if cfg.smoke { 3 } else { 8 };
    let (l_serial, p_serial, _) =
        dp_trajectory(&cfg, &loader, &layout, shard_config(&cfg, 2, false, false), det_steps);
    let (l_par, p_par, _) =
        dp_trajectory(&cfg, &loader, &layout, shard_config(&cfg, 2, true, false), det_steps);
    let sync_bitwise = l_serial == l_par && p_serial == p_par;
    println!(
        "\nthreaded == serial at W=2 (bitwise, losses + final params): {}",
        if sync_bitwise { "PASS" } else { "FAIL" }
    );
    assert!(sync_bitwise, "threaded data-parallel SVI diverged from serial");

    // ---- 3b. graph mode: compiled == dynamic, thread-invariant ----
    let (l_graph, p_graph, diags) =
        dp_trajectory(&cfg, &loader, &layout, shard_config(&cfg, 2, false, true), det_steps);
    assert!(
        diags.active,
        "graph mode failed to engage on the DMM: {:?}",
        diags.last_error
    );
    assert_eq!(diags.fallbacks, 0, "graph mode fell back mid-bench: {:?}", diags.last_error);
    let graph_matches_dynamic = l_graph
        .iter()
        .zip(&l_serial)
        .all(|(g, d)| (g - d).abs() <= 1e-12 * (1.0 + d.abs()));
    let (l_graph_par, p_graph_par, _) =
        dp_trajectory(&cfg, &loader, &layout, shard_config(&cfg, 2, true, true), det_steps);
    let graph_thread_invariant = l_graph == l_graph_par && p_graph == p_graph_par;
    println!(
        "graph == dynamic (1e-12): {} | graph threaded == serial (bitwise): {}",
        if graph_matches_dynamic { "PASS" } else { "FAIL" },
        if graph_thread_invariant { "PASS" } else { "FAIL" }
    );
    assert!(graph_matches_dynamic, "compiled shard trajectory diverged from dynamic");
    assert!(graph_thread_invariant, "compiled shard trajectory is thread-dependent");
    let t_graph =
        dp_step_loop(&cfg, &loader, &layout, shard_config(&cfg, 2, true, true), "graph");
    let t_dyn_w2 =
        dp_step_loop(&cfg, &loader, &layout, shard_config(&cfg, 2, true, false), "dyn-w2");
    let graph_speedup = t_dyn_w2.ns_per_iter() / t_graph.ns_per_iter();
    println!("graph-mode speedup vs dynamic at W=2: {graph_speedup:.2}x");

    // ---- 4. on-disk streaming reproduces the in-memory run bitwise ----
    let stream_path = std::env::temp_dir().join("fyro_fig4_stream.bin");
    let stream_path = stream_path.to_str().expect("utf8 temp path");
    let flat_rows: Vec<Vec<f32>> = rolls
        .iter()
        .map(|r| r.iter().flatten().copied().collect())
        .collect();
    StreamLoader::create(
        stream_path,
        &[cfg.t, cfg.xd],
        flat_rows.iter().map(|r| r.as_slice()),
    )
    .expect("writing stream file");
    let streamed = StreamLoader::open(stream_path).expect("opening stream file");
    let (l_stream, p_stream, _) =
        dp_trajectory(&cfg, &streamed, &layout, shard_config(&cfg, 2, true, false), det_steps);
    let stream_matches_mem = l_stream == l_par && p_stream == p_par;
    println!(
        "on-disk StreamLoader == MemLoader (bitwise): {}",
        if stream_matches_mem { "PASS" } else { "FAIL" }
    );
    assert!(stream_matches_mem, "streaming loader changed the training trajectory");
    std::fs::remove_file(stream_path).ok();

    // ---- 5. async parameter server ----
    let async_steps = if cfg.smoke { 6 } else { 40 };
    let model = make_dmm_model(&cfg);
    let guide = make_dmm_guide(&cfg);
    let server = ParamServer::new(ParamStore::new(), Adam::new(0.003), 4);
    let t0 = std::time::Instant::now();
    let report = train_async(
        &server,
        &TraceElbo::default(),
        &loader,
        &layout,
        &AsyncConfig::new(2, cfg.batch, async_steps),
        &model,
        &guide,
    )
    .expect("async training");
    let async_secs = t0.elapsed().as_secs_f64();
    let async_rows_per_sec = (report.applied as usize * cfg.batch) as f64 / async_secs;
    let async_tail = report.tail_mean(async_steps);
    println!(
        "async (W=2, k=4): {} applied / {} rejected pushes, {async_rows_per_sec:.0} rows/sec, \
         tail loss {async_tail:.3}",
        report.applied, report.rejected
    );
    assert!(async_tail.is_finite(), "async training produced non-finite losses");

    // ---- machine-readable record ----
    let out_path =
        std::env::var("FYRO_BENCH_OUT").unwrap_or_else(|_| "BENCH_fig4.json".to_string());
    let record = JsonObj::new()
        .str("bench", "fig4_dmm_dataparallel")
        .str("unit", "ns_per_step_median")
        .obj(
            "config",
            JsonObj::new()
                .int("t", cfg.t)
                .int("z", cfg.zd)
                .int("x", cfg.xd)
                .int("batch_per_shard", cfg.batch)
                .int("rows", cfg.rows)
                .int("iters", cfg.iters)
                .int("hw_threads", hw_threads)
                .bool("smoke", cfg.smoke),
        )
        .int("data_loop_allocs", data_loop_allocs as usize)
        .arr("sweep", sweep_rows)
        .num("thread_speedup_w2", speedup_w2)
        .bool("sync_bitwise", sync_bitwise)
        .obj(
            "graph",
            JsonObj::new()
                .bool("active", diags.active)
                .bool("matches_dynamic_1e12", graph_matches_dynamic)
                .bool("thread_invariant", graph_thread_invariant)
                .num("speedup_vs_dynamic", graph_speedup),
        )
        .bool("stream_matches_mem", stream_matches_mem)
        .obj(
            "async",
            JsonObj::new()
                .int("workers", 2)
                .int("max_staleness", 4)
                .int("applied", report.applied as usize)
                .int("rejected", report.rejected as usize)
                .num("rows_per_sec", async_rows_per_sec)
                .num("tail_loss", async_tail),
        );
    record.write(&out_path).expect("writing bench record");
    println!("record -> {out_path}");
    println!(
        "\nshape check: rows/sec should grow with W on idle multi-core machines;\n\
         the W=2 thread speedup is CI-gated at >= 1.6x on full runs."
    );
}
