//! Paper Figure 3, dynamic-path edition: time per VAE SVI gradient
//! update, **pre-optimization baseline vs the current hot path**, in
//! one binary.
//!
//! The baseline re-enables the retained reference implementations:
//! per-element `unravel` broadcast kernels (`tensor::set_reference_
//! kernels`), clone-and-add adjoint accumulation, and the allocating
//! Adam (`optim::reference::AdamRef`) — i.e. the state of the crate
//! before the stride-aware/allocation-free rework. The optimized side
//! runs the strided kernels, in-place tape accumulation and the fused
//! in-place Adam. A second section measures multi-particle ELBO
//! scaling (serial vs worker threads) and asserts the parallel path is
//! bitwise-deterministic. A third section pits the vectorized `plate`
//! (one broadcast site per plate) against the retained sequential
//! `plate_seq` (one site per data point) at N=1024, asserting the two
//! produce the same ELBO to 1e-10 and recording ns/step + allocs/step
//! for both. A fourth section measures per-estimator score-gradient
//! variance (Trace vs Rao-Blackwellized TraceGraph vs Rényi/IWAE) on
//! the discrete-latent gmm, asserting TraceGraph never raises variance
//! over plain Trace. A final section gates the telemetry layer: the
//! enabled-vs-disabled overhead on the compiled hot path (≤2% on full
//! runs), zero allocations per telemetry-enabled compiled step, and
//! bitwise-identical loss trajectories with telemetry on vs off.
//!
//! Output: a human table on stdout plus a machine-readable record at
//! `$FYRO_BENCH_OUT` (default `BENCH_fig3.json`) with ns/step, an
//! allocations-per-step proxy (counting-allocator delta), particle and
//! thread counts — the perf trajectory is tracked from these records.
//!
//! Knobs: FYRO_BENCH_ITERS (default 40), FYRO_BENCH_SMOKE=1 (tiny
//! dims + 4 iters, for the 2-second CI smoke).
//!
//! Run: `cargo bench --bench fig3_vae_overhead`.

use fyro::benchkit::{self, json::JsonObj, Table};
use fyro::infer::svi::{trace_pair, Svi, SviConfig};
use fyro::infer::{ParticleCtx, ParticleStats};
use fyro::nn::{Activation, Linear, Mlp};
use fyro::optim::reference::AdamRef;
use fyro::optim::{Adam, Optimizer};
use fyro::params::ParamStore;
use fyro::poutine::Ctx;
use fyro::prelude::*;
use fyro::telemetry;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

// ------------------------------------------------- allocations proxy

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

// --------------------------------------------------------- the model

#[derive(Clone, Copy)]
struct Cfg {
    zd: usize,
    h: usize,
    xd: usize,
    batch: usize,
    iters: usize,
    warmup: usize,
    smoke: bool,
}

impl Cfg {
    fn from_env() -> Cfg {
        let smoke = std::env::var("FYRO_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
        let iters: usize = std::env::var("FYRO_BENCH_ITERS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(if smoke { 4 } else { 40 });
        if smoke {
            Cfg { zd: 4, h: 16, xd: 64, batch: 8, iters, warmup: 1, smoke }
        } else {
            Cfg { zd: 10, h: 64, xd: 196, batch: 32, iters, warmup: 3, smoke }
        }
    }
}

fn binary_batch(cfg: &Cfg) -> Tensor {
    let mut rng = Pcg64::new(0xDA7A);
    let data: Vec<f64> = (0..cfg.batch * cfg.xd)
        .map(|_| f64::from(rng.uniform() < 0.35))
        .collect();
    Tensor::new(data, vec![cfg.batch, cfg.xd])
}

/// model(x): z ~ N(0, I)^[batch, zd]; x ~ Bernoulli(decoder(z)),
/// declared inside a vectorized `plate` over the mini-batch (one
/// broadcast site per plate, the batch dim carried by the dist shapes).
fn make_model(cfg: &Cfg, x: Tensor) -> impl Fn(&mut Ctx) + Sync {
    let (zd, h, xd, batch) = (cfg.zd, cfg.h, cfg.xd, cfg.batch);
    move |ctx: &mut Ctx| {
        ctx.plate("batch", batch, None, |ctx, _plate| {
            let loc = ctx.c(Tensor::zeros(vec![batch, zd]));
            let scale = ctx.c(Tensor::ones(vec![batch, zd]));
            let z = ctx.sample("z", MvNormalDiag::new(loc, scale));
            let dec = Mlp::new("dec", &[zd, h, xd], Activation::Tanh, Activation::Identity);
            let logits = dec.forward(ctx, &z);
            // to_event(1): pixels are event dims, so both sites' batch
            // shape is [batch] — aligned with the plate's allocated dim
            ctx.observe("x", Bernoulli::new(logits).to_event(1), x.clone());
        });
    }
}

/// guide(x): z ~ N(encoder(x))
fn make_guide(cfg: &Cfg, x: Tensor) -> impl Fn(&mut Ctx) + Sync {
    let (zd, h, xd, _batch) = (cfg.zd, cfg.h, cfg.xd, cfg.batch);
    move |ctx: &mut Ctx| {
        let enc = Mlp::new("enc", &[xd, h], Activation::Tanh, Activation::Tanh);
        let head_loc = Linear::new("enc.loc", h, zd);
        let head_ls = Linear::new("enc.ls", h, zd);
        let xv = ctx.c(x.clone());
        let hh = enc.forward(ctx, &xv);
        let loc = head_loc.forward(ctx, &hh);
        let scale = head_ls.forward(ctx, &hh).mul_scalar(0.25).exp();
        ctx.sample("z", MvNormalDiag::new(loc, scale));
    }
}

// ------------------------------------------------------- measurement

/// Time `f` and report (timing, allocations per measured iteration).
fn measure(
    label: &str,
    warmup: usize,
    iters: usize,
    mut f: impl FnMut(),
) -> (benchkit::Timing, f64) {
    for _ in 0..warmup {
        f();
    }
    let a0 = ALLOCS.load(Ordering::Relaxed);
    let t = benchkit::bench(label, 0, iters, f);
    let allocs = (ALLOCS.load(Ordering::Relaxed) - a0) as f64 / iters.max(1) as f64;
    (t, allocs)
}

fn svi_loop<O: Optimizer>(
    cfg: &Cfg,
    opt: O,
    svi_cfg: SviConfig,
    label: &str,
) -> (benchkit::Timing, f64) {
    let x = binary_batch(cfg);
    let model = make_model(cfg, x.clone());
    let guide = make_guide(cfg, x);
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(7);
    let mut svi = Svi::with_config(opt, TraceElbo::default(), svi_cfg);
    measure(label, cfg.warmup, cfg.iters, || {
        std::hint::black_box(svi.step(&mut store, &mut rng, &model, &guide));
    })
}

/// Graph-mode variant: warmup must cover the recording step (dynamic)
/// AND the first compiled step (arena construction), so the measured
/// iterations see only the steady-state straight-line kernel.
fn svi_loop_compiled(cfg: &Cfg, svi_cfg: SviConfig, label: &str) -> (benchkit::Timing, f64) {
    let x = binary_batch(cfg);
    let model = make_model(cfg, x.clone());
    let guide = make_guide(cfg, x);
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(7);
    let mut svi = Svi::with_config(Adam::new(0.003), TraceElbo::default(), svi_cfg);
    let out = measure(label, cfg.warmup.max(2), cfg.iters, || {
        std::hint::black_box(svi.step(&mut store, &mut rng, &model, &guide));
    });
    let d = svi.graph_diagnostics();
    assert!(
        d.active,
        "graph mode failed to engage on the VAE model: {:?}",
        d.last_error
    );
    assert_eq!(d.fallbacks, 0, "graph mode fell back mid-bench: {:?}", d.last_error);
    out
}

/// Loss trajectory under a given config (determinism checks).
fn loss_trajectory(cfg: &Cfg, svi_cfg: SviConfig, steps: usize) -> Vec<f64> {
    let x = binary_batch(cfg);
    let model = make_model(cfg, x.clone());
    let guide = make_guide(cfg, x);
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(21);
    let mut svi = Svi::with_config(Adam::new(0.003), TraceElbo::default(), svi_cfg);
    (0..steps)
        .map(|_| svi.step(&mut store, &mut rng, &model, &guide))
        .collect()
}

// --------------------------------------------- telemetry overhead ----

/// Interleaved windows of compiled steps with telemetry off vs on, so
/// clock/thermal drift hits both sides equally. Returns (median ns/step
/// off, median ns/step on, allocs/step in the enabled windows). The
/// allocation figure takes the min across windows — the harness itself
/// may allocate (stdout, timers) but the steady-state step must not.
fn telemetry_overhead(cfg: &Cfg) -> (f64, f64, f64) {
    let x = binary_batch(cfg);
    let model = make_model(cfg, x.clone());
    let guide = make_guide(cfg, x);
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(7);
    let mut svi = Svi::with_config(
        Adam::new(0.003),
        TraceElbo::default(),
        SviConfig { graph_mode: true, ..SviConfig::default() },
    );
    for _ in 0..cfg.warmup.max(2) {
        svi.step(&mut store, &mut rng, &model, &guide);
    }
    let windows = if cfg.smoke { 5 } else { 15 };
    let per = cfg.iters.max(4);
    let mut off_ns = Vec::with_capacity(windows);
    let mut on_ns = Vec::with_capacity(windows);
    let mut on_allocs = u64::MAX;
    for _ in 0..windows {
        telemetry::set_enabled(false);
        let t0 = std::time::Instant::now();
        for _ in 0..per {
            std::hint::black_box(svi.step(&mut store, &mut rng, &model, &guide));
        }
        off_ns.push(t0.elapsed().as_nanos() as f64 / per as f64);
        telemetry::set_enabled(true);
        let a0 = ALLOCS.load(Ordering::Relaxed);
        let t0 = std::time::Instant::now();
        for _ in 0..per {
            std::hint::black_box(svi.step(&mut store, &mut rng, &model, &guide));
        }
        let dt = t0.elapsed().as_nanos() as f64 / per as f64;
        let da = ALLOCS.load(Ordering::Relaxed) - a0;
        telemetry::set_enabled(false);
        on_ns.push(dt);
        on_allocs = on_allocs.min(da);
    }
    off_ns.sort_by(f64::total_cmp);
    on_ns.sort_by(f64::total_cmp);
    (
        benchkit::percentile(&off_ns, 0.5),
        benchkit::percentile(&on_ns, 0.5),
        on_allocs as f64 / per as f64,
    )
}

/// Same-seed loss trajectories with telemetry off vs on must be
/// bit-for-bit equal — the determinism contract, checked on the live
/// bench model rather than a toy.
fn telemetry_bitwise_match(cfg: &Cfg, svi_cfg: SviConfig, steps: usize) -> bool {
    telemetry::set_enabled(false);
    let off = loss_trajectory(cfg, svi_cfg, steps);
    telemetry::set_enabled(true);
    let on = loss_trajectory(cfg, svi_cfg, steps);
    telemetry::set_enabled(false);
    off.len() == on.len()
        && off.iter().zip(&on).all(|(a, b)| a.to_bits() == b.to_bits())
}

// ------------------------------- vectorized vs sequential plate -----

/// Gaussian-mean model over `data` with ONE vectorized plate site.
fn make_plate_model_vec(data: Tensor) -> impl Fn(&mut Ctx) + Sync {
    move |ctx: &mut Ctx| {
        let mu = ctx.sample("mu", Normal::std(0.0, 10.0));
        let n = data.dims()[0];
        ctx.plate("data", n, None, |ctx, plate| {
            ctx.observe("x", Normal::new(mu.clone(), ctx.cs(1.0)), plate.select(&data));
        });
    }
}

/// The same model through the retained sequential `plate_seq`: one
/// string-named scalar site per data point (the pre-redesign API).
fn make_plate_model_seq(data: Tensor) -> impl Fn(&mut Ctx) + Sync {
    move |ctx: &mut Ctx| {
        let mu = ctx.sample("mu", Normal::std(0.0, 10.0));
        let n = data.dims()[0];
        ctx.plate_seq("data", n, None, |ctx, idx| {
            for &i in idx {
                ctx.observe(
                    &format!("x_{i}"),
                    Normal::new(mu.clone(), ctx.cs(1.0)),
                    Tensor::scalar(data.data()[i]),
                );
            }
        });
    }
}

fn plate_guide(ctx: &mut Ctx) {
    let loc = ctx.param("mu.loc", || Tensor::scalar(0.0));
    let scale =
        ctx.param_constrained("mu.scale", || Tensor::scalar(0.5), Constraint::Positive);
    ctx.sample("mu", Normal::new(loc, scale));
}

fn plate_svi_loop(
    model: &(impl Fn(&mut Ctx) + Sync),
    warmup: usize,
    iters: usize,
    label: &str,
) -> (benchkit::Timing, f64) {
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(3);
    let mut svi = Svi::with_config(Adam::new(0.01), TraceElbo::default(), SviConfig::default());
    measure(label, warmup, iters, || {
        std::hint::black_box(svi.step(&mut store, &mut rng, model, &plate_guide));
    })
}

/// One-step loss with a fresh store/seed (path-equivalence check).
fn plate_one_step_loss(model: &(impl Fn(&mut Ctx) + Sync)) -> f64 {
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(0xE1B0);
    let mut svi = Svi::with_config(Adam::new(0.01), TraceElbo::default(), SviConfig::default());
    svi.step(&mut store, &mut rng, model, &plate_guide)
}

// ------------------- ELBO estimator gradient variance (gmm) ---------

/// The gmm example's model at bench scale: two latent cluster means and
/// ONE batched Categorical assignment site (`[n, 2]` logits) inside a
/// full plate — the score-function showcase where plate-aware
/// Rao-Blackwellization should measurably cut gradient variance.
fn make_gmm_model(n: usize, data: Tensor) -> impl Fn(&mut Ctx) + Sync {
    move |ctx: &mut Ctx| {
        let mu0 = ctx.sample("mu0", Normal::std(0.0, 10.0));
        let mu1 = ctx.sample("mu1", Normal::std(0.0, 10.0));
        ctx.plate("data", n, None, |ctx, _plate| {
            let prior = ctx.c(Tensor::zeros(vec![n, 2]));
            let k = ctx.sample("assign", Categorical::new(prior));
            let one_minus = k.neg().add_scalar(1.0);
            let mu = mu0.mul(&one_minus).add(&mu1.mul(&k));
            ctx.observe("x", Normal::new(mu, ctx.cs(0.5)), data.clone());
        });
    }
}

fn make_gmm_guide(n: usize) -> impl Fn(&mut Ctx) + Sync {
    move |ctx: &mut Ctx| {
        for m in ["mu0", "mu1"] {
            let init = if m == "mu0" { -1.0 } else { 1.0 };
            let loc = ctx.param(&format!("{m}.loc"), move || Tensor::scalar(init));
            let scale = ctx.param_constrained(
                &format!("{m}.scale"),
                || Tensor::scalar(0.1),
                Constraint::Positive,
            );
            ctx.sample(m, Normal::new(loc, scale));
        }
        ctx.plate("data", n, None, |ctx, _plate| {
            let logits = ctx.param("assign.logits", || Tensor::zeros(vec![n, 2]));
            ctx.sample("assign", Categorical::new(logits));
        });
    }
}

/// Measure the estimator's score-gradient variance w.r.t. the discrete
/// guide site's logits at a fixed parameter point: each round combines
/// `particles` per-particle gradients with the estimator's `combine`
/// weights (exactly SVI's merge), absorbs the observations so baselines
/// advance as in real training, and records the combined gradient.
/// Returns (mean per-coordinate variance across rounds, ns per round).
fn elbo_grad_variance<E: Elbo>(
    mut est: E,
    particles: usize,
    rounds: usize,
    warmup: usize,
    model: &(impl Fn(&mut Ctx) + Sync),
    guide: &(impl Fn(&mut Ctx) + Sync),
) -> (f64, f64) {
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(0x6313);
    let mut samples: Vec<Vec<f64>> = Vec::with_capacity(rounds);
    let t0 = std::time::Instant::now();
    for r in 0..rounds + warmup {
        let snap = est.snapshot();
        let mut stats: Vec<ParticleStats> = Vec::with_capacity(particles);
        let mut grads: Vec<Vec<f64>> = Vec::with_capacity(particles);
        for _ in 0..particles {
            let (mt, gt) = trace_pair(&mut store, &mut rng, model, guide);
            let mut pctx = ParticleCtx::new(&snap);
            let (loss, value) =
                est.differentiable_loss(&mt, &gt, &mut pctx).expect("elbo evaluation");
            let leaf = &gt.param_leaves["assign.logits"];
            let g = loss.tape().grad(&loss, &[leaf]).remove(0);
            grads.push(g.data().to_vec());
            stats.push(ParticleStats { value, obs: pctx.obs });
        }
        let (_, weights) = est.combine(&stats);
        let dim = grads[0].len();
        let mut combined = vec![0.0; dim];
        for (g, &w) in grads.iter().zip(&weights) {
            for (c, x) in combined.iter_mut().zip(g) {
                *c += w * x;
            }
        }
        est.absorb(&stats);
        if r >= warmup {
            samples.push(combined);
        }
    }
    let ns = t0.elapsed().as_nanos() as f64 / (rounds + warmup) as f64;
    let dim = samples[0].len();
    let m = samples.len() as f64;
    let mut var_acc = 0.0;
    for d in 0..dim {
        let mean: f64 = samples.iter().map(|s| s[d]).sum::<f64>() / m;
        var_acc += samples.iter().map(|s| (s[d] - mean).powi(2)).sum::<f64>() / m;
    }
    (var_acc / dim as f64, ns)
}

fn main() {
    let cfg = Cfg::from_env();
    let hw_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "Figure 3 (dynamic path): VAE SVI step, baseline vs optimized hot path\n\
         (z={}, h={}, x={}, batch={}, {} iters{}; {hw_threads} cores)\n",
        cfg.zd,
        cfg.h,
        cfg.xd,
        cfg.batch,
        cfg.iters,
        if cfg.smoke { ", SMOKE" } else { "" },
    );

    // ---- single-particle: pre-change baseline vs current hot path ----
    let serial = SviConfig { num_particles: 1, parallel: false, ..SviConfig::default() };
    fyro::tensor::set_reference_kernels(true);
    let (t_base, allocs_base) = svi_loop(&cfg, AdamRef::new(0.003), serial, "baseline");
    fyro::tensor::set_reference_kernels(false);
    let (t_opt, allocs_opt) = svi_loop(&cfg, Adam::new(0.003), serial, "optimized");
    let speedup = t_base.ns_per_iter() / t_opt.ns_per_iter();

    let mut table = Table::new(&["path", "ns/step", "allocs/step", "speedup"]);
    table.row(&[
        "baseline (unravel + AdamRef)".into(),
        format!("{:.0}", t_base.ns_per_iter()),
        format!("{allocs_base:.0}"),
        "1.00x".into(),
    ]);
    table.row(&[
        "optimized (strided + fused)".into(),
        format!("{:.0}", t_opt.ns_per_iter()),
        format!("{allocs_opt:.0}"),
        format!("{speedup:.2}x"),
    ]);
    table.print();

    // ---- graph mode: record once, replay a straight-line fused kernel ----
    let (t_cmp, allocs_cmp) =
        svi_loop_compiled(&cfg, SviConfig { graph_mode: true, ..SviConfig::default() }, "compiled");
    let speedup_cmp = t_opt.ns_per_iter() / t_cmp.ns_per_iter();
    let mut cmp_table = Table::new(&["path", "ns/step", "allocs/step", "speedup vs dynamic"]);
    cmp_table.row(&[
        "dynamic (strided + fused)".into(),
        format!("{:.0}", t_opt.ns_per_iter()),
        format!("{allocs_opt:.0}"),
        "1.00x".into(),
    ]);
    cmp_table.row(&[
        "compiled (graph mode)".into(),
        format!("{:.0}", t_cmp.ns_per_iter()),
        format!("{allocs_cmp:.0}"),
        format!("{speedup_cmp:.2}x"),
    ]);
    println!();
    cmp_table.print();
    assert_eq!(
        allocs_cmp, 0.0,
        "compiled graph-mode step must be allocation-free in steady state"
    );

    // ---- multi-particle ELBO: serial vs worker threads ----
    let particles = 4usize;
    let mk = |parallel: bool, threads: usize| SviConfig {
        num_particles: particles,
        parallel,
        num_threads: threads,
        ..SviConfig::default()
    };
    let mut mp_rows = Vec::new();
    let mut mp_table = Table::new(&["mode", "particles", "threads", "ns/step", "scaling"]);
    let (t_mp_serial, _) = svi_loop(&cfg, Adam::new(0.003), mk(false, 0), "mp-serial");
    let mut thread_counts = vec![2usize];
    if hw_threads > 2 {
        thread_counts.push(hw_threads.min(particles));
    }
    thread_counts.dedup();
    let mut results = vec![("serial".to_string(), 1usize, t_mp_serial.ns_per_iter())];
    for &tc in &thread_counts {
        let (t_par, _) = svi_loop(&cfg, Adam::new(0.003), mk(true, tc), "mp-parallel");
        results.push((format!("parallel x{tc}"), tc, t_par.ns_per_iter()));
    }
    for (mode, threads, ns) in &results {
        let scaling = t_mp_serial.ns_per_iter() / ns;
        mp_table.row(&[
            mode.clone(),
            particles.to_string(),
            threads.to_string(),
            format!("{ns:.0}"),
            format!("{scaling:.2}x"),
        ]);
        mp_rows.push(
            JsonObj::new()
                .str("mode", mode)
                .int("particles", particles)
                .int("threads", *threads)
                .num("ns_per_step", *ns)
                .num("scaling_vs_serial", scaling),
        );
    }
    println!();
    mp_table.print();

    // ---- vectorized plate vs retained sequential plate_seq ----
    let plate_n = 1024usize;
    let plate_data = {
        let mut prng = Pcg64::new(0x91A7E);
        Tensor::randn(vec![plate_n], &mut prng).mul_scalar(0.5).add_scalar(1.0)
    };
    let plate_vec = make_plate_model_vec(plate_data.clone());
    let plate_seq = make_plate_model_seq(plate_data.clone());
    let mut trng = Pcg64::new(1);
    let sites_vec = fyro::poutine::trace_fn(&plate_vec, &mut trng).len();
    let mut trng = Pcg64::new(1);
    let sites_seq = fyro::poutine::trace_fn(&plate_seq, &mut trng).len();
    assert_eq!(sites_vec, 2, "a vectorized plate of N must record ONE site (+1 latent)");
    assert_eq!(sites_seq, plate_n + 1);
    let loss_vec = plate_one_step_loss(&plate_vec);
    let loss_seq = plate_one_step_loss(&plate_seq);
    let plate_elbo_matches =
        (loss_vec - loss_seq).abs() <= 1e-10 * (1.0 + loss_seq.abs());
    assert!(
        plate_elbo_matches,
        "vectorized vs sequential plate ELBO diverged: {loss_vec} vs {loss_seq}"
    );
    let (t_pvec, allocs_pvec) =
        plate_svi_loop(&plate_vec, cfg.warmup, cfg.iters, "plate-vectorized");
    let (t_pseq, allocs_pseq) =
        plate_svi_loop(&plate_seq, cfg.warmup, cfg.iters, "plate-sequential");
    let mut plate_table = Table::new(&["plate path (N=1024)", "sites", "ns/step", "allocs/step"]);
    plate_table.row(&[
        "vectorized (1 site)".into(),
        sites_vec.to_string(),
        format!("{:.0}", t_pvec.ns_per_iter()),
        format!("{allocs_pvec:.0}"),
    ]);
    plate_table.row(&[
        "sequential plate_seq".into(),
        sites_seq.to_string(),
        format!("{:.0}", t_pseq.ns_per_iter()),
        format!("{allocs_pseq:.0}"),
    ]);
    println!();
    plate_table.print();
    println!(
        "plate speedup {:.2}x, ELBO match (1e-10): {}",
        t_pseq.ns_per_iter() / t_pvec.ns_per_iter(),
        if plate_elbo_matches { "PASS" } else { "FAIL" }
    );

    // ---- ELBO estimators: score-gradient variance on the gmm ----
    let gmm_n = 16usize;
    let gmm_data = {
        let mut grng = Pcg64::new(9);
        let pts: Vec<f64> = (0..gmm_n)
            .map(|i| {
                if i % 2 == 0 {
                    -2.0 + 0.5 * grng.normal()
                } else {
                    3.0 + 0.5 * grng.normal()
                }
            })
            .collect();
        Tensor::from_vec(pts)
    };
    let gmm_model = make_gmm_model(gmm_n, gmm_data);
    let gmm_guide = make_gmm_guide(gmm_n);
    let (elbo_rounds, elbo_warm) = if cfg.smoke { (60, 10) } else { (200, 20) };
    let (var_trace, ns_trace) = elbo_grad_variance(
        TraceElbo::default(),
        1,
        elbo_rounds,
        elbo_warm,
        &gmm_model,
        &gmm_guide,
    );
    let (var_graph, ns_graph) = elbo_grad_variance(
        TraceGraphElbo::default(),
        1,
        elbo_rounds,
        elbo_warm,
        &gmm_model,
        &gmm_guide,
    );
    let renyi_particles = 4usize;
    let (var_renyi, ns_renyi) = elbo_grad_variance(
        RenyiElbo::iwae(),
        renyi_particles,
        elbo_rounds,
        elbo_warm,
        &gmm_model,
        &gmm_guide,
    );
    let mut elbo_table =
        Table::new(&["estimator (gmm n=16)", "particles", "grad var", "ns/round"]);
    for (name, p, v, ns) in [
        ("Trace", 1, var_trace, ns_trace),
        ("TraceGraph", 1, var_graph, ns_graph),
        ("Renyi/IWAE", renyi_particles, var_renyi, ns_renyi),
    ] {
        elbo_table.row(&[
            name.into(),
            p.to_string(),
            format!("{v:.4}"),
            format!("{ns:.0}"),
        ]);
    }
    println!();
    elbo_table.print();
    println!(
        "TraceGraph / Trace gradient-variance ratio: {:.3} (must be <= 1)",
        var_graph / var_trace
    );
    assert!(
        var_graph <= var_trace,
        "Rao-Blackwellized TraceGraph must not raise gradient variance on the \
         discrete-latent gmm: {var_graph} vs {var_trace}"
    );

    // ---- determinism: parallel == serial, bitwise ----
    let det_steps = if cfg.smoke { 3 } else { 10 };
    let serial_losses = loss_trajectory(&cfg, mk(false, 0), det_steps);
    let parallel_losses = loss_trajectory(&cfg, mk(true, 2), det_steps);
    let deterministic = serial_losses == parallel_losses;
    println!(
        "\nparallel == serial (bitwise, {det_steps} steps): {}",
        if deterministic { "PASS" } else { "FAIL" }
    );
    assert!(deterministic, "parallel ELBO diverged from serial");

    // ---- graph-mode equivalence: compiled vs dynamic, and bitwise parallel ----
    let compiled_losses = loss_trajectory(
        &cfg,
        SviConfig { graph_mode: true, ..SviConfig::default() },
        det_steps,
    );
    let dynamic_losses = loss_trajectory(&cfg, SviConfig::default(), det_steps);
    let compiled_matches_dynamic = compiled_losses
        .iter()
        .zip(&dynamic_losses)
        .all(|(c, d)| (c - d).abs() <= 1e-12 * (1.0 + d.abs()));
    let gmk = |parallel: bool, threads: usize| SviConfig {
        num_particles: particles,
        parallel,
        num_threads: threads,
        graph_mode: true,
        ..SviConfig::default()
    };
    let compiled_deterministic =
        loss_trajectory(&cfg, gmk(false, 0), det_steps) == loss_trajectory(&cfg, gmk(true, 2), det_steps);
    println!(
        "compiled == dynamic (1e-12, {det_steps} steps): {} | compiled parallel == serial (bitwise): {}",
        if compiled_matches_dynamic { "PASS" } else { "FAIL" },
        if compiled_deterministic { "PASS" } else { "FAIL" }
    );
    assert!(compiled_matches_dynamic, "compiled trajectory diverged from dynamic (1e-12)");
    assert!(compiled_deterministic, "compiled parallel ELBO diverged from compiled serial");

    // ---- telemetry: off-path overhead, on-path allocations, parity ----
    telemetry::reset();
    let (ns_tel_off, ns_tel_on, allocs_tel_on) = telemetry_overhead(&cfg);
    let tel_overhead_pct = (ns_tel_on / ns_tel_off - 1.0) * 100.0;
    let tel_bitwise = telemetry_bitwise_match(&cfg, SviConfig::default(), det_steps)
        && telemetry_bitwise_match(
            &cfg,
            SviConfig { graph_mode: true, ..SviConfig::default() },
            det_steps,
        );
    // a clean enabled run of the compiled trajectory feeds the snapshot
    // embedded in the bench record (and the dashboard below)
    telemetry::reset();
    telemetry::set_enabled(true);
    let _ = loss_trajectory(
        &cfg,
        SviConfig { graph_mode: true, ..SviConfig::default() },
        det_steps,
    );
    telemetry::set_enabled(false);
    let tel_snapshot = telemetry::snapshot();

    let mut tel_table = Table::new(&["compiled step", "ns/step", "allocs/step", "overhead"]);
    tel_table.row(&[
        "telemetry off".into(),
        format!("{ns_tel_off:.0}"),
        "0".into(),
        "-".into(),
    ]);
    tel_table.row(&[
        "telemetry on".into(),
        format!("{ns_tel_on:.0}"),
        format!("{allocs_tel_on:.0}"),
        format!("{tel_overhead_pct:+.2}%"),
    ]);
    println!();
    tel_table.print();
    println!(
        "telemetry bitwise parity (dynamic + graph, {det_steps} steps): {}",
        if tel_bitwise { "PASS" } else { "FAIL" }
    );
    println!("\n{tel_snapshot}");
    assert_eq!(
        allocs_tel_on, 0.0,
        "telemetry-enabled compiled step must stay allocation-free"
    );
    assert!(tel_bitwise, "telemetry perturbed the loss trajectory");
    if !cfg.smoke {
        assert!(
            tel_overhead_pct <= 2.0,
            "telemetry-on overhead {tel_overhead_pct:.2}% exceeds the 2% budget"
        );
    }

    // ---- static analysis: linter sweep + verifier/DCE audit ----
    // The linter must stay silent on every known-good program (the VAE
    // pair and the example zoo), and the liveness DCE pass must be
    // provably free: same loss bits, same adjoint bits, same RNG state.
    let x = binary_batch(&cfg);
    let vae_model = make_model(&cfg, x.clone());
    let vae_guide = make_guide(&cfg, x);
    let mut lint_store = ParamStore::new();
    let vae_hint = fyro::analysis::EstimatorHint { name: "Trace", variance_reduced: false };
    let vae_report = fyro::analysis::lint_model_guide(
        &mut lint_store,
        23,
        &vae_model,
        &vae_guide,
        Some(&vae_hint),
    );
    assert!(vae_report.is_clean(), "VAE pair should lint clean: {vae_report}");
    let zoo_pairs = fyro::analysis::zoo::all();
    let mut zoo_diags = 0usize;
    for pair in &zoo_pairs {
        let mut store = ParamStore::new();
        let report = fyro::analysis::lint_model_guide(
            &mut store,
            11,
            &pair.model,
            &pair.guide,
            Some(&pair.estimator),
        );
        zoo_diags += report.len();
    }
    assert_eq!(zoo_diags, 0, "the example zoo must lint clean");
    let mut audit_store = ParamStore::new();
    let audit = fyro::infer::dce_audit(
        23,
        &mut audit_store,
        &vae_model,
        &vae_guide,
        &TraceElbo::default(),
    )
    .expect("the VAE pair is compilable");
    println!(
        "\nanalysis: lint clean on VAE + {} zoo pairs | IR verified | DCE: \
         {}/{} backward instruction(s) eliminated, bitwise match: {}",
        zoo_pairs.len(),
        audit.bw_eliminated,
        audit.bw_total,
        if audit.bitwise_match { "PASS" } else { "FAIL" }
    );
    assert!(audit.bitwise_match, "DCE changed the training trajectory");
    assert!(audit.bw_eliminated >= 1, "expected dead adjoint work into data leaves");

    // ---- machine-readable record ----
    let out_path =
        std::env::var("FYRO_BENCH_OUT").unwrap_or_else(|_| "BENCH_fig3.json".to_string());
    let record = JsonObj::new()
        .str("bench", "fig3_vae_overhead")
        .str("unit", "ns_per_step_median")
        .obj(
            "config",
            JsonObj::new()
                .int("z", cfg.zd)
                .int("h", cfg.h)
                .int("x", cfg.xd)
                .int("batch", cfg.batch)
                .int("iters", cfg.iters)
                .int("hw_threads", hw_threads)
                .bool("smoke", cfg.smoke),
        )
        .obj(
            "baseline",
            JsonObj::new()
                .num("ns_per_step", t_base.ns_per_iter())
                .num("allocs_per_step", allocs_base)
                .int("particles", 1)
                .int("threads", 1)
                .str("kernels", "reference-unravel")
                .str("optimizer", "AdamRef (allocating)"),
        )
        .obj(
            "optimized",
            JsonObj::new()
                .num("ns_per_step", t_opt.ns_per_iter())
                .num("allocs_per_step", allocs_opt)
                .int("particles", 1)
                .int("threads", 1)
                .str("kernels", "strided")
                .str("optimizer", "Adam (fused in-place)"),
        )
        .num("speedup", speedup)
        .obj(
            "compiled",
            JsonObj::new()
                .num("ns_per_step", t_cmp.ns_per_iter())
                .num("allocs_per_step", allocs_cmp)
                .num("speedup_vs_dynamic", speedup_cmp)
                .int("particles", 1)
                .int("threads", 1)
                .bool("matches_dynamic_1e12", compiled_matches_dynamic)
                .bool("parallel_matches_serial", compiled_deterministic)
                .str("kernels", "straight-line fused tape replay"),
        )
        .arr("multi_particle", mp_rows)
        .bool("parallel_matches_serial", deterministic)
        .obj(
            "elbo",
            JsonObj::new()
                .int("n", gmm_n)
                .int("rounds", elbo_rounds)
                .obj(
                    "trace",
                    JsonObj::new()
                        .num("grad_var", var_trace)
                        .num("ns_per_step", ns_trace)
                        .int("particles", 1),
                )
                .obj(
                    "tracegraph",
                    JsonObj::new()
                        .num("grad_var", var_graph)
                        .num("ns_per_step", ns_graph)
                        .int("particles", 1),
                )
                .obj(
                    "renyi_iwae",
                    JsonObj::new()
                        .num("grad_var", var_renyi)
                        .num("ns_per_step", ns_renyi)
                        .int("particles", renyi_particles),
                )
                .bool("tracegraph_le_trace", var_graph <= var_trace),
        )
        .obj(
            "plate",
            JsonObj::new()
                .int("n", plate_n)
                .obj(
                    "vectorized",
                    JsonObj::new()
                        .int("sites", sites_vec)
                        .num("ns_per_step", t_pvec.ns_per_iter())
                        .num("allocs_per_step", allocs_pvec),
                )
                .obj(
                    "sequential",
                    JsonObj::new()
                        .int("sites", sites_seq)
                        .num("ns_per_step", t_pseq.ns_per_iter())
                        .num("allocs_per_step", allocs_pseq),
                )
                .bool("elbo_matches", plate_elbo_matches),
        )
        .obj(
            "telemetry",
            JsonObj::new()
                .num("ns_per_step_compiled_off", ns_tel_off)
                .num("ns_per_step_compiled_on", ns_tel_on)
                .num("overhead_pct", tel_overhead_pct)
                .num("allocs_per_step_compiled_on", allocs_tel_on)
                .bool("bitwise_match", tel_bitwise)
                .obj("snapshot", tel_snapshot.to_json()),
        )
        .obj(
            "analysis",
            audit
                .to_json()
                .bool("verifier_ran", true)
                .int("zoo_pairs", zoo_pairs.len())
                .int("zoo_diagnostics", zoo_diags)
                .bool("vae_pair_clean", vae_report.is_clean()),
        );
    record.write(&out_path).expect("writing bench record");
    println!("record -> {out_path}");
    println!(
        "\nshape check: the optimized single-particle step should be >= 3x the\n\
         baseline, and parallel x2 should approach 2x on idle 2+ core machines."
    );
}
