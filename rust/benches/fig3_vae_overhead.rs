//! Paper Figure 3, dynamic-path edition: time per VAE SVI gradient
//! update, **pre-optimization baseline vs the current hot path**, in
//! one binary.
//!
//! The baseline re-enables the retained reference implementations:
//! per-element `unravel` broadcast kernels (`tensor::set_reference_
//! kernels`), clone-and-add adjoint accumulation, and the allocating
//! Adam (`optim::reference::AdamRef`) — i.e. the state of the crate
//! before the stride-aware/allocation-free rework. The optimized side
//! runs the strided kernels, in-place tape accumulation and the fused
//! in-place Adam. A second section measures multi-particle ELBO
//! scaling (serial vs worker threads) and asserts the parallel path is
//! bitwise-deterministic.
//!
//! Output: a human table on stdout plus a machine-readable record at
//! `$FYRO_BENCH_OUT` (default `BENCH_fig3.json`) with ns/step, an
//! allocations-per-step proxy (counting-allocator delta), particle and
//! thread counts — the perf trajectory is tracked from these records.
//!
//! Knobs: FYRO_BENCH_ITERS (default 40), FYRO_BENCH_SMOKE=1 (tiny
//! dims + 4 iters, for the 2-second CI smoke).
//!
//! Run: `cargo bench --bench fig3_vae_overhead`.

use fyro::benchkit::{self, json::JsonObj, Table};
use fyro::infer::svi::{Svi, SviConfig};
use fyro::nn::{Activation, Linear, Mlp};
use fyro::optim::reference::AdamRef;
use fyro::optim::{Adam, Optimizer};
use fyro::params::ParamStore;
use fyro::poutine::Ctx;
use fyro::prelude::*;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

// ------------------------------------------------- allocations proxy

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

// --------------------------------------------------------- the model

#[derive(Clone, Copy)]
struct Cfg {
    zd: usize,
    h: usize,
    xd: usize,
    batch: usize,
    iters: usize,
    warmup: usize,
    smoke: bool,
}

impl Cfg {
    fn from_env() -> Cfg {
        let smoke = std::env::var("FYRO_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
        let iters: usize = std::env::var("FYRO_BENCH_ITERS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(if smoke { 4 } else { 40 });
        if smoke {
            Cfg { zd: 4, h: 16, xd: 64, batch: 8, iters, warmup: 1, smoke }
        } else {
            Cfg { zd: 10, h: 64, xd: 196, batch: 32, iters, warmup: 3, smoke }
        }
    }
}

fn binary_batch(cfg: &Cfg) -> Tensor {
    let mut rng = Pcg64::new(0xDA7A);
    let data: Vec<f64> = (0..cfg.batch * cfg.xd)
        .map(|_| f64::from(rng.uniform() < 0.35))
        .collect();
    Tensor::new(data, vec![cfg.batch, cfg.xd])
}

/// model(x): z ~ N(0, I)^[batch, zd]; x ~ Bernoulli(decoder(z))
fn make_model(cfg: &Cfg, x: Tensor) -> impl Fn(&mut Ctx) + Sync {
    let (zd, h, xd, batch) = (cfg.zd, cfg.h, cfg.xd, cfg.batch);
    move |ctx: &mut Ctx| {
        let loc = ctx.c(Tensor::zeros(vec![batch, zd]));
        let scale = ctx.c(Tensor::ones(vec![batch, zd]));
        let z = ctx.sample("z", MvNormalDiag::new(loc, scale));
        let dec = Mlp::new("dec", &[zd, h, xd], Activation::Tanh, Activation::Identity);
        let logits = dec.forward(ctx, &z);
        ctx.observe("x", Bernoulli::new(logits), x.clone());
    }
}

/// guide(x): z ~ N(encoder(x))
fn make_guide(cfg: &Cfg, x: Tensor) -> impl Fn(&mut Ctx) + Sync {
    let (zd, h, xd, _batch) = (cfg.zd, cfg.h, cfg.xd, cfg.batch);
    move |ctx: &mut Ctx| {
        let enc = Mlp::new("enc", &[xd, h], Activation::Tanh, Activation::Tanh);
        let head_loc = Linear::new("enc.loc", h, zd);
        let head_ls = Linear::new("enc.ls", h, zd);
        let xv = ctx.c(x.clone());
        let hh = enc.forward(ctx, &xv);
        let loc = head_loc.forward(ctx, &hh);
        let scale = head_ls.forward(ctx, &hh).mul_scalar(0.25).exp();
        ctx.sample("z", MvNormalDiag::new(loc, scale));
    }
}

// ------------------------------------------------------- measurement

/// Time `f` and report (timing, allocations per measured iteration).
fn measure(
    label: &str,
    warmup: usize,
    iters: usize,
    mut f: impl FnMut(),
) -> (benchkit::Timing, f64) {
    for _ in 0..warmup {
        f();
    }
    let a0 = ALLOCS.load(Ordering::Relaxed);
    let t = benchkit::bench(label, 0, iters, f);
    let allocs = (ALLOCS.load(Ordering::Relaxed) - a0) as f64 / iters.max(1) as f64;
    (t, allocs)
}

fn svi_loop<O: Optimizer>(
    cfg: &Cfg,
    opt: O,
    svi_cfg: SviConfig,
    label: &str,
) -> (benchkit::Timing, f64) {
    let x = binary_batch(cfg);
    let model = make_model(cfg, x.clone());
    let guide = make_guide(cfg, x);
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(7);
    let mut svi = Svi::with_config(opt, svi_cfg);
    measure(label, cfg.warmup, cfg.iters, || {
        std::hint::black_box(svi.step(&mut store, &mut rng, &model, &guide));
    })
}

/// Loss trajectory under a given config (determinism checks).
fn loss_trajectory(cfg: &Cfg, svi_cfg: SviConfig, steps: usize) -> Vec<f64> {
    let x = binary_batch(cfg);
    let model = make_model(cfg, x.clone());
    let guide = make_guide(cfg, x);
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(21);
    let mut svi = Svi::with_config(Adam::new(0.003), svi_cfg);
    (0..steps)
        .map(|_| svi.step(&mut store, &mut rng, &model, &guide))
        .collect()
}

fn main() {
    let cfg = Cfg::from_env();
    let hw_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "Figure 3 (dynamic path): VAE SVI step, baseline vs optimized hot path\n\
         (z={}, h={}, x={}, batch={}, {} iters{}; {hw_threads} cores)\n",
        cfg.zd,
        cfg.h,
        cfg.xd,
        cfg.batch,
        cfg.iters,
        if cfg.smoke { ", SMOKE" } else { "" },
    );

    // ---- single-particle: pre-change baseline vs current hot path ----
    let serial = SviConfig { num_particles: 1, parallel: false, ..SviConfig::default() };
    fyro::tensor::set_reference_kernels(true);
    let (t_base, allocs_base) = svi_loop(&cfg, AdamRef::new(0.003), serial, "baseline");
    fyro::tensor::set_reference_kernels(false);
    let (t_opt, allocs_opt) = svi_loop(&cfg, Adam::new(0.003), serial, "optimized");
    let speedup = t_base.ns_per_iter() / t_opt.ns_per_iter();

    let mut table = Table::new(&["path", "ns/step", "allocs/step", "speedup"]);
    table.row(&[
        "baseline (unravel + AdamRef)".into(),
        format!("{:.0}", t_base.ns_per_iter()),
        format!("{allocs_base:.0}"),
        "1.00x".into(),
    ]);
    table.row(&[
        "optimized (strided + fused)".into(),
        format!("{:.0}", t_opt.ns_per_iter()),
        format!("{allocs_opt:.0}"),
        format!("{speedup:.2}x"),
    ]);
    table.print();

    // ---- multi-particle ELBO: serial vs worker threads ----
    let particles = 4usize;
    let mk = |parallel: bool, threads: usize| SviConfig {
        num_particles: particles,
        parallel,
        num_threads: threads,
        ..SviConfig::default()
    };
    let mut mp_rows = Vec::new();
    let mut mp_table = Table::new(&["mode", "particles", "threads", "ns/step", "scaling"]);
    let (t_mp_serial, _) = svi_loop(&cfg, Adam::new(0.003), mk(false, 0), "mp-serial");
    let mut thread_counts = vec![2usize];
    if hw_threads > 2 {
        thread_counts.push(hw_threads.min(particles));
    }
    thread_counts.dedup();
    let mut results = vec![("serial".to_string(), 1usize, t_mp_serial.ns_per_iter())];
    for &tc in &thread_counts {
        let (t_par, _) = svi_loop(&cfg, Adam::new(0.003), mk(true, tc), "mp-parallel");
        results.push((format!("parallel x{tc}"), tc, t_par.ns_per_iter()));
    }
    for (mode, threads, ns) in &results {
        let scaling = t_mp_serial.ns_per_iter() / ns;
        mp_table.row(&[
            mode.clone(),
            particles.to_string(),
            threads.to_string(),
            format!("{ns:.0}"),
            format!("{scaling:.2}x"),
        ]);
        mp_rows.push(
            JsonObj::new()
                .str("mode", mode)
                .int("particles", particles)
                .int("threads", *threads)
                .num("ns_per_step", *ns)
                .num("scaling_vs_serial", scaling),
        );
    }
    println!();
    mp_table.print();

    // ---- determinism: parallel == serial, bitwise ----
    let det_steps = if cfg.smoke { 3 } else { 10 };
    let serial_losses = loss_trajectory(&cfg, mk(false, 0), det_steps);
    let parallel_losses = loss_trajectory(&cfg, mk(true, 2), det_steps);
    let deterministic = serial_losses == parallel_losses;
    println!(
        "\nparallel == serial (bitwise, {det_steps} steps): {}",
        if deterministic { "PASS" } else { "FAIL" }
    );
    assert!(deterministic, "parallel ELBO diverged from serial");

    // ---- machine-readable record ----
    let out_path =
        std::env::var("FYRO_BENCH_OUT").unwrap_or_else(|_| "BENCH_fig3.json".to_string());
    let record = JsonObj::new()
        .str("bench", "fig3_vae_overhead")
        .str("unit", "ns_per_step_median")
        .obj(
            "config",
            JsonObj::new()
                .int("z", cfg.zd)
                .int("h", cfg.h)
                .int("x", cfg.xd)
                .int("batch", cfg.batch)
                .int("iters", cfg.iters)
                .int("hw_threads", hw_threads)
                .bool("smoke", cfg.smoke),
        )
        .obj(
            "baseline",
            JsonObj::new()
                .num("ns_per_step", t_base.ns_per_iter())
                .num("allocs_per_step", allocs_base)
                .int("particles", 1)
                .int("threads", 1)
                .str("kernels", "reference-unravel")
                .str("optimizer", "AdamRef (allocating)"),
        )
        .obj(
            "optimized",
            JsonObj::new()
                .num("ns_per_step", t_opt.ns_per_iter())
                .num("allocs_per_step", allocs_opt)
                .int("particles", 1)
                .int("threads", 1)
                .str("kernels", "strided")
                .str("optimizer", "Adam (fused in-place)"),
        )
        .num("speedup", speedup)
        .arr("multi_particle", mp_rows)
        .bool("parallel_matches_serial", deterministic);
    record.write(&out_path).expect("writing bench record");
    println!("record -> {out_path}");
    println!(
        "\nshape check: the optimized single-particle step should be >= 3x the\n\
         baseline, and parallel x2 should approach 2x on idle 2+ core machines."
    );
}
