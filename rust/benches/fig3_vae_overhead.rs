//! Paper Figure 3: time per VAE gradient update, PPL path vs bare path,
//! for (#z, #h) ∈ {10,30} × {400,2000} at batch 128.
//!
//! Paper's numbers (GTX 1080Ti, PyTorch vs Pyro, ms/update):
//!   z=10 h=400 : 3.82 vs 6.79 (1.78x)     z=30 h=400 : 3.73 vs 6.67 (1.79x)
//!   z=10 h=2000: 7.65 vs 10.14 (1.33x)    z=30 h=2000: 7.66 vs 10.19 (1.33x)
//! Expected *shape* on this CPU testbed: a moderate constant overhead
//! for the traced path whose relative share SHRINKS as #h grows.
//!
//! Run: `cargo bench --bench fig3_vae_overhead` (after `make artifacts`).

use fyro::benchkit::{bench_pair, Table};
use fyro::coordinator::CompiledSvi;
use fyro::data::{gather_images, SyntheticMnist};
use fyro::params::ParamStore;
use fyro::runtime::{ArtifactCache, F32Buf};

fn main() -> anyhow::Result<()> {
    let iters: usize = std::env::var("FYRO_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(15);
    let cache = ArtifactCache::open("artifacts")?;
    let mut table = Table::new(&[
        "#z", "#h", "raw median ms", "fyro median ms", "ppl-only ms", "overhead", "paper overhead",
    ]);
    let paper = [(10, 400, 1.78), (30, 400, 1.79), (10, 2000, 1.33), (30, 2000, 1.33)];

    println!("Figure 3 reproduction: VAE ms/update, bare artifact vs full PPL path");
    println!("(batch 128, synthetic MNIST, PJRT CPU; {iters} iters each)\n");

    for (z, h, paper_ratio) in paper {
        let name = format!("vae_z{z}_h{h}");
        let model = cache.load(&name)?;
        let meta = model.meta.clone();
        let data = SyntheticMnist::generate(meta.batch * 2, 0, 1);
        let idx: Vec<usize> = (0..meta.batch).collect();
        let x = F32Buf { data: gather_images(&data.train, &idx), dims: meta.x_dims.clone() };

        // interleaved A/B so single-core drift cancels; median reported
        let mut svi = CompiledSvi::new(model, 7)?;
        let model2 = cache.load(&name)?;
        let mut svi2 = CompiledSvi::new(model2, 7)?;
        let mut store = ParamStore::new();
        let (raw, traced) = bench_pair(
            &format!("{name} raw"),
            &format!("{name} fyro"),
            3,
            iters,
            || {
                svi.step_raw(&x).unwrap();
            },
            || {
                svi2.step_traced(&x, &mut store).unwrap();
            },
        );

        // machinery in isolation (it is below the compiled-step noise)
        let mut svi3 = CompiledSvi::new(cache.load(&name)?, 7)?;
        let mut store3 = ParamStore::new();
        let ppl = fyro::benchkit::bench(&format!("{name} ppl"), 3, iters.max(30), || {
            std::hint::black_box(svi3.trace_machinery_only(&x, &mut store3));
        });

        table.row(&[
            z.to_string(),
            h.to_string(),
            format!("{:.2} (±{:.2})", raw.median_ms, raw.std_ms),
            format!("{:.2} (±{:.2})", traced.median_ms, traced.std_ms),
            format!("{:.2}", ppl.median_ms),
            format!("{:.2}x", (raw.median_ms + ppl.median_ms) / raw.median_ms),
            format!("{paper_ratio:.2}x"),
        ]);
    }
    table.print();
    println!(
        "\nshape check: overhead ratio at h=2000 should be below the h=400 ratio\n\
         (abstraction cost amortizes as tensor work grows — paper §5)"
    );
    Ok(())
}
