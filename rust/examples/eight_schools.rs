//! The classic "eight schools" hierarchical model with NUTS — the
//! paper's non-variational inference path (Hoffman & Gelman 2014).
//!
//! y_j ~ N(theta_j, sigma_j);  theta_j = mu + tau * eta_j;
//! mu ~ N(0, 5);  tau ~ HalfCauchy(5);  eta_j ~ N(0, 1).
//! (non-centered parameterization, as standard for NUTS)
//!
//! Run: `cargo run --release --example eight_schools`

use fyro::infer::mcmc::{McmcConfig, Nuts};
use fyro::prelude::*;

const Y: [f64; 8] = [28.0, 8.0, -3.0, 7.0, -1.0, 1.0, 18.0, 12.0];
const SIGMA: [f64; 8] = [15.0, 10.0, 16.0, 11.0, 9.0, 11.0, 10.0, 18.0];

fn main() {
    let model = |ctx: &mut Ctx| {
        let mu = ctx.sample("mu", Normal::std(0.0, 5.0));
        let tau = ctx.sample("tau", HalfCauchy::std(5.0));
        let eta = ctx.sample(
            "eta",
            MvNormalDiag::new(
                ctx.c(Tensor::zeros(vec![8])),
                ctx.c(Tensor::ones(vec![8])),
            ),
        );
        let theta = mu.add(&tau.mul(&eta));
        ctx.observe(
            "y",
            Normal::new(theta, ctx.c(Tensor::from_vec(SIGMA.to_vec()))),
            Tensor::from_vec(Y.to_vec()),
        );
    };

    println!("running NUTS (500 warmup, 1000 samples) ...");
    let cfg = McmcConfig { warmup: 500, samples: 1000, seed: 11, ..Default::default() };
    let out = Nuts::run(&model, cfg);
    println!(
        "accept rate {:.2}, step size {:.4}, mean tree depth {:.1}\n",
        out.accept_rate, out.step_size, out.mean_tree_depth
    );

    let mu = out.mean("mu").item();
    let mu_sd = out.std("mu").item();
    let tau = out.mean("tau").item();
    println!("posterior:");
    println!("  mu  = {mu:>6.2} ± {mu_sd:.2}   (Stan reference ~ 8 ± 5)");
    println!("  tau = {tau:>6.2}          (Stan reference ~ 6.5)");
    let eta = out.mean("eta");
    println!("  eta = {:?}", eta.data().iter().map(|v| (v * 100.0).round() / 100.0).collect::<Vec<_>>());

    assert!((2.0..14.0).contains(&mu), "mu {mu} outside plausible band");
    assert!(tau > 1.0 && tau < 15.0, "tau {tau} outside plausible band");
    assert!(out.accept_rate > 0.5, "poor acceptance {}", out.accept_rate);
    println!("\neight_schools OK");
}
