//! Data-parallel SVI, synchronous and asynchronous, on a toy Gaussian.
//!
//! Run: `cargo run --example data_parallel`
//!
//! Demonstrates the three pieces introduced for multi-worker training:
//! - `ShardedLoader` / `MemLoader` / `StreamLoader`: stream epoch
//!   batches per shard without materializing the dataset.
//! - `DataParallelSvi`: W shards, gradients merged deterministically in
//!   shard order — thread count changes throughput, never results.
//! - `coordinator::ParamServer` + `train_async`: workers pull versioned
//!   snapshots and push gradient deltas, staleness-bounded.

use fyro::coordinator::{train_async, AsyncConfig, ParamServer};
use fyro::infer::ShardBatch;
use fyro::prelude::*;

/// model: mu ~ N(0, 10); each observed row x_i ~ N(mu, 1), declared
/// inside an index-subsampled plate (the driver picks the indices).
fn model(ctx: &mut Ctx, b: &ShardBatch) {
    let mu = ctx.sample("mu", Normal::std(0.0, 10.0));
    let x = b.views[0].clone().reshape(vec![b.idx.len()]);
    ctx.plate_idx("data", b.total, b.idx, |ctx, _| {
        ctx.observe("x", Normal::new(mu.clone(), ctx.cs(1.0)), x);
    });
}

fn guide(ctx: &mut Ctx, _b: &ShardBatch) {
    let loc = ctx.param("mu_loc", || Tensor::scalar(0.0));
    let scale = ctx.param_constrained("mu_scale", || Tensor::scalar(1.0), Constraint::Positive);
    ctx.sample("mu", Normal::new(loc, scale));
}

fn main() -> fyro::error::Result<()> {
    // a dataset whose mean is 2.0
    let rows: Vec<Vec<f32>> = (0..64).map(|i| vec![2.0 + 0.1 * (i as f32 - 31.5)]).collect();
    let loader = MemLoader::from_images(&rows);
    let layout = BatchLayout::single(&[1]);

    // ---- synchronous: 4 shards, threaded == serial bitwise ----
    let sweep = [("serial ", false), ("threaded", true)];
    let mut finals = Vec::new();
    for (label, parallel) in sweep {
        let sc = ShardConfig { parallel, ..ShardConfig::new(4, 8) };
        let mut dp =
            DataParallelSvi::new(Adam::new(0.05), TraceElbo::default(), sc, layout.clone());
        let mut store = ParamStore::new();
        let mut rng = Pcg64::new(7);
        let mut loss = f64::NAN;
        for _ in 0..300 {
            loss = dp.step(&mut store, &mut rng, &loader, &model, &guide)?;
        }
        let loc = store.get("mu_loc").unwrap().item();
        println!("sync {label}: final loss {loss:.4}, mu_loc {loc:.4}");
        finals.push((loss, loc));
    }
    assert_eq!(finals[0], finals[1], "thread count must be invisible in the results");
    println!("threaded == serial: bitwise PASS");

    // ---- asynchronous: parameter server, staleness-bounded ----
    let server = ParamServer::new(ParamStore::new(), Adam::new(0.05), 4);
    let report = train_async(
        &server,
        &TraceElbo::default(),
        &loader,
        &layout,
        &AsyncConfig::new(4, 8, 75),
        &model,
        &guide,
    )?;
    let loc = server.into_store().get("mu_loc").unwrap().item();
    println!(
        "async: {} applied / {} rejected pushes, mu_loc {loc:.4} (sync got {:.4})",
        report.applied, report.rejected, finals[0].1
    );
    Ok(())
}
