//! Quickstart: Bayesian linear regression with SVI on the dynamic path.
//!
//! The Fyro rendering of the pyro.ai getting-started example: infer the
//! slope/intercept/noise of a linear relationship from 50 noisy points,
//! with a hand-written mean-field guide. No artifacts needed.
//!
//! Run: `cargo run --release --example quickstart`

use fyro::infer::svi::SviConfig;
use fyro::prelude::*;
use fyro::telemetry;

fn main() {
    // metrics are off by default (one relaxed atomic load per probe);
    // turning them on never changes training results — same RNG
    // stream, same losses, bit for bit
    telemetry::set_enabled(true);
    // ---- synthetic data: y = 1.8 x - 0.7 + N(0, 0.4) ----
    let mut data_rng = Pcg64::new(42);
    let n = 50;
    let xs: Vec<f64> = (0..n).map(|i| -2.0 + 4.0 * i as f64 / n as f64).collect();
    let ys: Vec<f64> = xs
        .iter()
        .map(|&x| 1.8 * x - 0.7 + 0.4 * data_rng.normal())
        .collect();
    let xs_t = Tensor::from_vec(xs.clone());
    let ys_t = Tensor::from_vec(ys.clone());

    // ---- model ----
    let model = move |ctx: &mut Ctx| {
        let slope = ctx.sample("slope", Normal::std(0.0, 5.0));
        let intercept = ctx.sample("intercept", Normal::std(0.0, 5.0));
        let sigma = ctx.sample("sigma", LogNormal::std(-1.0, 0.7));
        let x = ctx.c(xs_t.clone());
        let mean = x.mul(&slope).add(&intercept);
        ctx.observe("y", Normal::new(mean, sigma), ys_t.clone());
    };

    // ---- mean-field guide ----
    let guide = |ctx: &mut Ctx| {
        for (site, init) in [("slope", 0.0), ("intercept", 0.0), ("sigma_log", -1.0)] {
            let loc = ctx.param(&format!("{site}.loc"), || Tensor::scalar(init));
            let scale = ctx.param_constrained(
                &format!("{site}.scale"),
                || Tensor::scalar(0.1),
                Constraint::Positive,
            );
            let name = site.strip_suffix("_log").unwrap_or(site);
            if site.ends_with("_log") {
                ctx.sample(name, LogNormal::new(loc, scale));
            } else {
                ctx.sample(name, Normal::new(loc, scale));
            }
        }
    };

    // ---- SVI ----
    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(0);
    // the loss is an estimator object (paper: SVI(..., loss=Trace_ELBO()));
    // the guide is fully reparameterized, so plain TraceElbo is right.
    // The model is also *static* (fixed site set and shapes), so graph
    // mode records the first step and replays a compiled straight-line
    // kernel for the rest — same losses to 1e-12, no trace machinery.
    let mut svi = Svi::with_config(
        Adam::new(0.05),
        TraceElbo::default(),
        SviConfig { num_particles: 2, graph_mode: true, ..SviConfig::default() },
    );
    println!("step      loss");
    for step in 0..2000 {
        let loss = svi.step(&mut store, &mut rng, &model, &guide);
        if step % 200 == 0 {
            println!("{step:>5} {loss:>9.3}");
        }
    }
    let d = svi.graph_diagnostics();
    println!(
        "\ngraph mode: {} compiled steps, {} dynamic, {} compile(s), {} fallback(s)",
        d.compiled_steps, d.dynamic_steps, d.compiles, d.fallbacks
    );
    assert!(d.active, "the quickstart model is static and must stay compiled");

    // ---- observability: the run left a full metric trail behind ----
    println!("\n{}", telemetry::snapshot());

    let slope = store.get("slope.loc").unwrap().item();
    let intercept = store.get("intercept.loc").unwrap().item();
    let sigma = store.get("sigma_log.loc").unwrap().item().exp();
    println!("\nposterior means (true values in parens):");
    println!("  slope     {slope:>7.3}  (1.8)");
    println!("  intercept {intercept:>7.3}  (-0.7)");
    println!("  sigma     {sigma:>7.3}  (0.4)");
    assert!((slope - 1.8).abs() < 0.2, "slope off: {slope}");
    assert!((intercept + 0.7).abs() < 0.2, "intercept off: {intercept}");
    println!("\nquickstart OK");
}
