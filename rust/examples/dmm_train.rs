//! Train the Deep Markov Model (paper §5 / Fig 4) on synthetic chorales,
//! optionally with IAF-extended guides.
//!
//! Prereq: `make artifacts`. Run:
//!   `cargo run --release --example dmm_train -- [num_iafs] [epochs]`

use fyro::coordinator::DmmTrainer;
use fyro::runtime::ArtifactCache;

fn main() -> fyro::error::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let iafs: usize = args.get(1).map(|s| s.parse().unwrap()).unwrap_or(1);
    let epochs: usize = args.get(2).map(|s| s.parse().unwrap()).unwrap_or(15);
    let name = format!("dmm_iaf{iafs}");

    let cache = match ArtifactCache::open("artifacts") {
        Ok(c) => c,
        Err(e) => {
            println!("skipping: compiled-path artifacts unavailable ({e})");
            return Ok(());
        }
    };
    println!("compiling {name} on PJRT CPU ...");
    let model = match cache.load(&name) {
        Ok(m) => m,
        Err(e) => {
            println!("skipping: compiled-path backend unavailable ({e})");
            return Ok(());
        }
    };
    println!(
        "model: {} params, batch {}, T {}, {} IAF flow(s)",
        model.meta.p,
        model.meta.batch,
        model.meta.x_dims[1],
        iafs
    );

    let mut trainer = DmmTrainer::new(model, 384, 64)?;
    println!("\nepoch  train -ELBO/t  test -ELBO/t");
    let mut first = f64::NAN;
    let mut last = f64::NAN;
    for e in 0..epochs {
        let s = trainer.run_epoch(e)?;
        if e == 0 {
            first = s.train_loss;
        }
        last = s.train_loss;
        println!("{:>5}  {:>12.4}  {:>12.4}", s.epoch, s.train_loss, s.test_loss);
    }
    assert!(last < first, "DMM did not learn: {first:.3} -> {last:.3}");
    println!("\ndmm_train OK ({first:.3} -> {last:.3} nats/t)");
    Ok(())
}
