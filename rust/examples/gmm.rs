//! Gaussian mixture with a discrete latent per point — demonstrates
//! score-function (REINFORCE) gradients through a non-reparameterizable
//! guide site, one of the expressiveness axes of paper Fig 2.
//!
//! Model: for each point, k ~ Categorical(pi); x ~ N(mu_k, 0.5).
//! We fit per-point assignment probabilities and the two cluster means.
//!
//! Vectorized-plate edition: the N assignments are ONE batched
//! Categorical site (logits `[N, 2]`, draws `[N]`) and the N
//! observations ONE broadcast Normal site, so every SVI step touches 4
//! sites total instead of 2 + 2N.
//!
//! Estimator: `TraceGraphElbo` — the batched assignment site sits in a
//! shared plate, so Rao-Blackwellization makes each point's REINFORCE
//! coefficient its OWN downstream cost (its assignment prior + its
//! likelihood term) instead of the whole-trace ELBO, cutting score
//! gradient variance by roughly the plate size. The fig3 bench's `elbo`
//! section measures exactly this on this model.
//!
//! Run: `cargo run --release --example gmm`

use fyro::infer::svi::SviConfig;
use fyro::prelude::*;

fn main() {
    // two well-separated clusters
    let mut drng = Pcg64::new(9);
    let mut data = Vec::new();
    for _ in 0..20 {
        data.push(-2.0 + 0.5 * drng.normal());
        data.push(3.0 + 0.5 * drng.normal());
    }
    let n = data.len();
    let data_t = Tensor::from_vec(data.clone());

    let data_m = data_t.clone();
    let model = move |ctx: &mut Ctx| {
        // cluster means with vague priors
        let mu0 = ctx.sample("mu0", Normal::std(0.0, 10.0));
        let mu1 = ctx.sample("mu1", Normal::std(0.0, 10.0));
        ctx.plate("data", n, None, |ctx, _plate| {
            // uniform prior over assignments: one [n, 2]-logit site
            let prior = ctx.c(Tensor::zeros(vec![n, 2]));
            let k = ctx.sample("assign", Categorical::new(prior));
            // select mu_k per point, differentiable in both means
            let one_minus = k.neg().add_scalar(1.0);
            let mu = mu0.mul(&one_minus).add(&mu1.mul(&k));
            ctx.observe("x", Normal::new(mu, ctx.cs(0.5)), data_m.clone());
        });
    };

    let guide = move |ctx: &mut Ctx| {
        for m in ["mu0", "mu1"] {
            let init = if m == "mu0" { -1.0 } else { 1.0 };
            let loc = ctx.param(&format!("{m}.loc"), move || Tensor::scalar(init));
            let scale = ctx.param_constrained(
                &format!("{m}.scale"),
                || Tensor::scalar(0.1),
                Constraint::Positive,
            );
            ctx.sample(m, Normal::new(loc, scale));
        }
        ctx.plate("data", n, None, |ctx, _plate| {
            let logits = ctx.param("assign.logits", || Tensor::zeros(vec![n, 2]));
            ctx.sample("assign", Categorical::new(logits));
        });
    };

    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(1);
    let mut svi = Svi::with_config(
        Adam::new(0.05),
        TraceGraphElbo::default(),
        SviConfig { num_particles: 4, ..SviConfig::default() },
    );
    println!("estimator: {}", svi.elbo.name());
    println!("step      loss");
    for step in 0..1500 {
        let loss = svi.step(&mut store, &mut rng, &model, &guide);
        if step % 150 == 0 {
            println!("{step:>5} {loss:>9.2}");
        }
    }

    let mut mu0 = store.get("mu0.loc").unwrap().item();
    let mut mu1 = store.get("mu1.loc").unwrap().item();
    if mu0 > mu1 {
        std::mem::swap(&mut mu0, &mut mu1);
    }
    println!("\ncluster means: {mu0:.2}, {mu1:.2}  (true: -2, 3)");
    assert!((mu0 + 2.0).abs() < 0.5, "mu0 {mu0}");
    assert!((mu1 - 3.0).abs() < 0.5, "mu1 {mu1}");

    // assignments follow the data: read the [n, 2] logits row-wise
    let logits = store.get("assign.logits").unwrap();
    let probs = logits.log_softmax_last().exp();
    let mut correct = 0;
    for (i, &x) in data.iter().enumerate() {
        let hard = usize::from(probs.data()[2 * i] <= probs.data()[2 * i + 1]);
        let truth = usize::from(x > 0.5);
        // cluster identity may be swapped; count both orientations
        if hard == truth {
            correct += 1;
        }
    }
    let acc = (correct as f64 / n as f64).max(1.0 - correct as f64 / n as f64);
    println!("assignment accuracy: {acc:.2}");
    assert!(acc > 0.9, "poor assignments: {acc}");
    println!("\ngmm OK");
}
