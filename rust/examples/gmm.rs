//! Gaussian mixture with a discrete latent per point — demonstrates
//! score-function (REINFORCE) gradients through a non-reparameterizable
//! guide site, one of the expressiveness axes of paper Fig 2.
//!
//! Model: for each point, k ~ Categorical(pi); x ~ N(mu_k, 0.5).
//! We fit per-point assignment probabilities and the two cluster means.
//!
//! Run: `cargo run --release --example gmm`

use fyro::infer::svi::SviConfig;
use fyro::prelude::*;

fn main() {
    // two well-separated clusters
    let mut drng = Pcg64::new(9);
    let mut data = Vec::new();
    for _ in 0..20 {
        data.push(-2.0 + 0.5 * drng.normal());
        data.push(3.0 + 0.5 * drng.normal());
    }
    let n = data.len();

    let data_m = data.clone();
    let model = move |ctx: &mut Ctx| {
        // cluster means with vague priors
        let mu0 = ctx.sample("mu0", Normal::std(0.0, 10.0));
        let mu1 = ctx.sample("mu1", Normal::std(0.0, 10.0));
        for (i, &x) in data_m.iter().enumerate() {
            let k = ctx.sample(&format!("k_{i}"), Categorical::from_weights(&[0.5, 0.5]));
            let kv = k.value().item();
            let mu = if kv < 0.5 { mu0.clone() } else { mu1.clone() };
            ctx.observe(&format!("x_{i}"), Normal::new(mu, ctx.cs(0.5)), Tensor::scalar(x));
        }
    };

    let guide = move |ctx: &mut Ctx| {
        for m in ["mu0", "mu1"] {
            let init = if m == "mu0" { -1.0 } else { 1.0 };
            let loc = ctx.param(&format!("{m}.loc"), move || Tensor::scalar(init));
            let scale = ctx.param_constrained(
                &format!("{m}.scale"),
                || Tensor::scalar(0.1),
                Constraint::Positive,
            );
            ctx.sample(m, Normal::new(loc, scale));
        }
        for i in 0..n {
            let logits = ctx.param(&format!("assign_{i}"), || Tensor::zeros(vec![2]));
            ctx.sample(&format!("k_{i}"), Categorical::new(logits));
        }
    };

    let mut store = ParamStore::new();
    let mut rng = Pcg64::new(1);
    let mut svi = Svi::with_config(
        Adam::new(0.05),
        SviConfig { num_particles: 4, ..SviConfig::default() },
    );
    println!("step      loss");
    for step in 0..1500 {
        let loss = svi.step(&mut store, &mut rng, &model, &guide);
        if step % 150 == 0 {
            println!("{step:>5} {loss:>9.2}");
        }
    }

    let mut mu0 = store.get("mu0.loc").unwrap().item();
    let mut mu1 = store.get("mu1.loc").unwrap().item();
    if mu0 > mu1 {
        std::mem::swap(&mut mu0, &mut mu1);
    }
    println!("\ncluster means: {mu0:.2}, {mu1:.2}  (true: -2, 3)");
    assert!((mu0 + 2.0).abs() < 0.5, "mu0 {mu0}");
    assert!((mu1 - 3.0).abs() < 0.5, "mu1 {mu1}");

    // assignments for the first few points follow the data
    let mut correct = 0;
    for (i, &x) in data.iter().enumerate() {
        let logits = store.get(&format!("assign_{i}")).unwrap();
        let probs = logits.log_softmax_last().exp();
        let hard = if probs.data()[0] > probs.data()[1] { 0 } else { 1 };
        let truth = usize::from(x > 0.5);
        // cluster identity may be swapped; count both orientations
        if hard == truth {
            correct += 1;
        }
    }
    let acc = (correct as f64 / n as f64).max(1.0 - correct as f64 / n as f64);
    println!("assignment accuracy: {acc:.2}");
    assert!(acc > 0.9, "poor assignments: {acc}");
    println!("\ngmm OK");
}
