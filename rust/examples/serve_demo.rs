//! Serving-layer demo: train a tiny model zoo, round-trip it through
//! `FYSNAP01` snapshots, freeze + register, serve a burst of concurrent
//! posterior queries, hot-swap a new version mid-flight, and print the
//! telemetry dashboard.
//!
//! Run: `cargo run --release --example serve_demo`

use fyro::serve::loadgen::{eight_schools_svi, vae_mini};
use fyro::serve::{Query, Registry, Request, Response, ServeConfig, Server};
use fyro::{coordinator, telemetry};
use std::sync::Arc;
use std::time::Instant;

const CLIENTS: usize = 16;
const REQS_PER_CLIENT: usize = 8;

fn score(server: &Server, model: &str, version: Option<u64>, seed: u64) -> f64 {
    let req = Request { model: model.to_string(), version, seed, query: Query::Score };
    match server.serve(req).expect("score request served") {
        Response::Score { loss, compiled } => {
            let path = if compiled { "compiled" } else { "dynamic" };
            println!("  {model} v{version:?} seed {seed}: loss {loss:.4} ({path} path)");
            loss
        }
        other => panic!("expected Score, got {other:?}"),
    }
}

fn main() -> fyro::error::Result<()> {
    telemetry::set_enabled(true);
    telemetry::reset();

    // 1. Train, snapshot to disk, load + freeze + register. load_frozen
    //    re-validates the store fingerprint and probes the pair against
    //    the frozen store, so a missing param fails here, not mid-request.
    let dir = std::env::temp_dir().join("fyro_serve_demo");
    std::fs::create_dir_all(&dir)?;
    let registry = Arc::new(Registry::new());
    println!("training zoo (vae v1, eight_schools v1) ...");
    for zm in [vae_mini(200), eight_schools_svi(200)] {
        let path = dir.join(format!("{}_v{}.snap", zm.name, zm.version));
        let path = path.to_str().expect("utf-8 temp path");
        coordinator::save_snapshot(path, zm.name, zm.version, &zm.store)?;
        let fm = registry.load_frozen(path, zm.model, zm.guide)?;
        println!(
            "  frozen '{}' v{}  ({} params, fingerprint {:016x})",
            fm.name(),
            fm.version(),
            fm.store().names().len(),
            fm.fingerprint()
        );
    }

    // 2. Serve a concurrent burst of mixed predictive/score queries.
    let server = Server::start(
        registry.clone(),
        ServeConfig { num_workers: 2, max_batch: 16, max_wait_us: 500, queue_depth: 128 },
    );
    println!("\nburst: {CLIENTS} clients x {REQS_PER_CLIENT} mixed requests ...");
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        let server = &server;
        for c in 0..CLIENTS {
            scope.spawn(move || {
                for r in 0..REQS_PER_CLIENT {
                    let (model, site) =
                        if (c + r) % 2 == 0 { ("vae", "x") } else { ("eight_schools", "y") };
                    let query = if (c + r) % 3 == 0 {
                        Query::Predictive { num_samples: 8, sites: vec![site.to_string()] }
                    } else {
                        Query::Score
                    };
                    let seed = ((c as u64) << 16) | r as u64;
                    server
                        .serve(Request { model: model.to_string(), version: None, seed, query })
                        .expect("burst request served");
                }
            });
        }
    });
    let burst_secs = t0.elapsed().as_secs_f64();
    println!(
        "  {} requests in {:.0} ms  ({:.0} req/s)",
        CLIENTS * REQS_PER_CLIENT,
        burst_secs * 1e3,
        (CLIENTS * REQS_PER_CLIENT) as f64 / burst_secs
    );

    // 3. One showcase posterior-predictive query.
    let resp = server
        .serve(Request {
            model: "eight_schools".to_string(),
            version: None,
            seed: 42,
            query: Query::Predictive { num_samples: 32, sites: vec!["y".to_string()] },
        })
        .expect("predictive served");
    if let Response::Predictive(map) = resp {
        let y = &map["y"];
        let mean = y.data().iter().sum::<f64>() / y.numel() as f64;
        println!("\nposterior predictive E[y] over 32 draws: {mean:.2}  (data mean 8.75)");
    }

    // 4. Hot-swap: register vae v2 (trained longer) while serving.
    //    New `version: None` requests resolve v2; pinned v1 still serves.
    println!("\nhot-swap: registering vae v2 while the server is live ...");
    let mut v2 = vae_mini(600);
    v2.version = 2;
    let path = dir.join("vae_v2.snap");
    let path = path.to_str().expect("utf-8 temp path");
    coordinator::save_snapshot(path, v2.name, v2.version, &v2.store)?;
    registry.load_frozen(path, v2.model, v2.guide)?;
    println!("  registered versions: {:?}", registry.versions("vae"));
    score(&server, "vae", Some(1), 5);
    score(&server, "vae", None, 5);

    // 5. Graceful shutdown, then the dashboard.
    server.shutdown();
    let snap = telemetry::snapshot();
    println!("\ntelemetry dashboard:");
    println!("  requests_served     {}", snap.counter("requests_served"));
    println!("  requests_rejected   {}", snap.counter("requests_rejected"));
    println!("  batches_dispatched  {}", snap.counter("batches_dispatched"));
    if let Some(h) = snap.hist("batch_fill") {
        println!("  batch_fill          mean {:.2}  p95 {:.0}", h.mean(), h.p95());
    }
    if let Some(h) = snap.hist("request_ns") {
        println!(
            "  request latency     p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms",
            h.p50() / 1e6,
            h.p95() / 1e6,
            h.p99() / 1e6
        );
    }
    if let Some(h) = snap.hist("queue_wait_ns") {
        println!(
            "  queue wait          p50 {:.2} ms  p95 {:.2} ms",
            h.p50() / 1e6,
            h.p95() / 1e6
        );
    }

    std::fs::remove_dir_all(&dir).ok();
    println!("\nserve_demo OK");
    Ok(())
}
