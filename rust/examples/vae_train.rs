//! End-to-end driver (the repo's headline validation run): train the
//! compiled-path VAE for several epochs on synthetic MNIST, proving all
//! three layers compose — Pallas kernels inside a JAX graph, AOT HLO
//! artifacts, PJRT execution under the Rust coordinator with the full
//! PPL (traced) step — and log the loss curve.
//!
//! Prereq: `make artifacts`. Run:
//!   `cargo run --release --example vae_train -- [epochs] [n_train]`

use fyro::coordinator::{StepPath, VaeTrainer};
use fyro::runtime::ArtifactCache;

fn main() -> fyro::error::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let epochs: usize = args.get(1).map(|s| s.parse().unwrap()).unwrap_or(6);
    let n_train: usize = args.get(2).map(|s| s.parse().unwrap()).unwrap_or(4096);

    let cache = match ArtifactCache::open("artifacts") {
        Ok(c) => c,
        Err(e) => {
            println!("skipping: compiled-path artifacts unavailable ({e})");
            return Ok(());
        }
    };
    println!("compiling vae_z10_h400 (init/train/eval) on PJRT CPU ...");
    let model = match cache.load("vae_z10_h400") {
        Ok(m) => m,
        Err(e) => {
            println!("skipping: compiled-path backend unavailable ({e})");
            return Ok(());
        }
    };
    let batch = model.meta.batch;
    println!(
        "model: {} params, batch {batch}, latent {}",
        model.meta.p, model.meta.eps_dims[1]
    );

    // Traced path: every step runs through the full PPL machinery.
    let mut trainer = VaeTrainer::new(model, n_train, 512, StepPath::Traced)?;
    println!("\nepoch  train -ELBO   test -ELBO   img/s   (loss curve -> EXPERIMENTS.md)");
    let mut curve = Vec::new();
    for e in 0..epochs {
        let s = trainer.run_epoch(e)?;
        println!(
            "{:>5}  {:>11.3}  {:>11.3}  {:>6.0}",
            s.epoch,
            s.train_loss,
            s.test_loss,
            s.throughput(batch)
        );
        curve.push((e, s.train_loss, s.test_loss));
    }

    // the run is only a success if the model actually learned
    let first = curve.first().unwrap().1;
    let last = curve.last().unwrap().1;
    assert!(
        last < first * 0.6,
        "train loss did not drop enough: {first:.1} -> {last:.1}"
    );
    println!("\nloss dropped {first:.1} -> {last:.1}; vae_train E2E OK");
    Ok(())
}
